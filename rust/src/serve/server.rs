//! Dynamic-batching policy server: one engine thread coalescing
//! concurrent single-observation queries into `forward_batch` calls.
//!
//! The serving loop is deadline-based: dequeuing the first query of a
//! batch opens a batching window of [`ServeConfig::window`]; every query
//! that lands before the deadline (up to [`ServeConfig::max_batch`])
//! joins the same GEMM. The window is anchored at dequeue time, not at
//! the first query's arrival, so under backlog a batch still gets a full
//! window to fill rather than dispatching undersized (the queueing delay
//! itself is visible in the latency histogram, whose clock *does* start
//! at arrival). Under heavy traffic the window never waits — the batch fills
//! first — so throughput approaches the engine's batched roofline; under
//! light traffic a query pays at most one window of extra latency.
//! Admission control is a bounded request queue: when it is full the
//! client's [`ServeClient::query`] fails fast with
//! [`QueryError::Overloaded`] instead of growing an unbounded backlog
//! (the rejected count is tallied in the final [`ServeReport`]).
//!
//! Because the engines' batched path is bit-identical per row to the
//! scalar path (pinned by `rust/tests/engine_parity.rs`), coalescing is
//! invisible to clients: a served query returns exactly the bytes a
//! direct [`Engine::forward`] call would have produced.
//!
//! # Lifecycle: Ready → Draining → exited
//!
//! The server starts **Ready** and serves until either every client
//! handle is dropped (the original teardown path) or someone calls
//! [`PolicyServer::begin_drain`] / [`PolicyServer::shutdown`]. A
//! **Draining** server flushes what is already queued — full batches,
//! no window waits — under a [`ServeConfig::drain`] deadline, then
//! rejects whatever remains (and any late submission) with
//! [`QueryError::Draining`]. Shutdown therefore completes even while
//! clients are still alive; the old "drop every client first or join
//! blocks forever" footgun is gone.
//!
//! The loop also watches for **stragglers**: a dispatched batch whose
//! wall time exceeds [`ServeConfig::slow_batch`] is tallied in
//! [`ServeReport::slow_batches`] (detection is off at the default
//! `Duration::ZERO`). A [`crate::faults::FaultPlan`] handed to
//! [`PolicyServer::spawn_faulted`] can stall scripted batches
//! (`slow_batch(nth, ms)`) to exercise the detector deterministically.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::faults::FaultPlan;
use crate::inference::Engine;
use crate::serve::stats::{BatchHist, LatencyHist, ServeReport};

/// Knobs for the batching front-end.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Largest batch one `forward_batch` call coalesces.
    pub max_batch: usize,
    /// Batching window: how long the server holds an open batch waiting
    /// for more queries after it dequeues the batch's first one.
    pub window: Duration,
    /// Bounded request-queue depth for admission control; submissions
    /// beyond it are rejected at the client.
    pub queue_capacity: usize,
    /// Drain budget: once draining begins, how long the loop may keep
    /// flushing queued requests before rejecting the remainder with
    /// [`QueryError::Draining`].
    pub drain: Duration,
    /// Straggler deadline: a dispatched batch slower than this counts
    /// toward [`ServeReport::slow_batches`]. `Duration::ZERO` (the
    /// default) disables detection.
    pub slow_batch: Duration,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 32,
            window: Duration::from_micros(250),
            queue_capacity: 1024,
            drain: Duration::from_millis(500),
            slow_batch: Duration::ZERO,
        }
    }
}

/// Why a query did not produce logits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueryError {
    /// Admission control bounced the query: the request queue was full.
    Overloaded,
    /// The server thread is gone (shut down or crashed).
    Closed,
    /// The server is draining: it is flushing already-queued work and
    /// accepts no new queries.
    Draining,
    /// The engine rejected the batch; every query in it gets the message.
    Engine(String),
    /// Observation width does not match the engine's input layer.
    Shape { got: usize, want: usize },
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::Overloaded => write!(f, "server overloaded (request queue full)"),
            QueryError::Closed => write!(f, "server closed"),
            QueryError::Draining => write!(f, "server draining (shutdown in progress)"),
            QueryError::Engine(m) => write!(f, "engine error: {m}"),
            QueryError::Shape { got, want } => {
                write!(f, "observation width {got}, engine expects {want}")
            }
        }
    }
}

impl std::error::Error for QueryError {}

/// State shared between clients, the server handle, and the serve
/// loop: the lifecycle flag plus the client-side reject counters.
struct ServeShared {
    draining: AtomicBool,
    rejected: AtomicU64,
    drain_rejected: AtomicU64,
}

/// One in-flight query: the observation, when it entered the queue (the
/// latency clock starts here, so queueing delay is part of what the
/// histogram sees), and where to send the logits.
struct Request {
    obs: Vec<f32>,
    enqueued: Instant,
    reply: SyncSender<Result<Vec<f32>, QueryError>>,
}

/// Client handle: submit observations, get logits. Cheap to clone; one
/// per querying thread. Clients may outlive the server: once a drain
/// begins (or the server exits) their queries fail fast with
/// [`QueryError::Draining`] / [`QueryError::Closed`] instead of
/// wedging shutdown.
#[derive(Clone)]
pub struct ServeClient {
    tx: SyncSender<Request>,
    shared: Arc<ServeShared>,
    in_dim: usize,
    out_dim: usize,
}

impl ServeClient {
    /// Blocking round-trip: enqueue `obs`, wait for its logits. Fails
    /// fast with [`QueryError::Overloaded`] when admission control
    /// bounces the submission (never blocks on a full queue) and with
    /// [`QueryError::Draining`] once shutdown has begun.
    pub fn query(&self, obs: &[f32]) -> Result<Vec<f32>, QueryError> {
        if obs.len() != self.in_dim {
            return Err(QueryError::Shape { got: obs.len(), want: self.in_dim });
        }
        if self.shared.draining.load(Ordering::SeqCst) {
            self.shared.drain_rejected.fetch_add(1, Ordering::Relaxed);
            return Err(QueryError::Draining);
        }
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = Request { obs: obs.to_vec(), enqueued: Instant::now(), reply: reply_tx };
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(QueryError::Overloaded);
            }
            Err(TrySendError::Disconnected(_)) => return Err(QueryError::Closed),
        }
        reply_rx.recv().unwrap_or(Err(QueryError::Closed))
    }

    /// Width of the logits vector a successful query returns.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }
}

/// The serving back-end: owns the engine thread. Built by
/// [`PolicyServer::spawn`] (or [`PolicyServer::spawn_faulted`] with a
/// chaos script); torn down by [`PolicyServer::shutdown`], which drains
/// and returns the run's [`ServeReport`].
pub struct PolicyServer {
    handle: JoinHandle<ServeReport>,
    shared: Arc<ServeShared>,
}

impl PolicyServer {
    /// Move `engine` onto a dedicated server thread and return the
    /// server plus the first [`ServeClient`] (clone it per querying
    /// thread).
    pub fn spawn<E: Engine + Send + 'static>(
        engine: E,
        cfg: ServeConfig,
    ) -> (PolicyServer, ServeClient) {
        PolicyServer::spawn_faulted(engine, cfg, None)
    }

    /// [`PolicyServer::spawn`] with an optional fault script: scripted
    /// `slow_batch(nth, ms)` entries stall the matching dispatch inside
    /// the serve thread (the injected stall counts toward the straggler
    /// deadline like any real slowdown).
    pub fn spawn_faulted<E: Engine + Send + 'static>(
        mut engine: E,
        cfg: ServeConfig,
        faults: Option<Arc<FaultPlan>>,
    ) -> (PolicyServer, ServeClient) {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity.max(1));
        let shared = Arc::new(ServeShared {
            draining: AtomicBool::new(false),
            rejected: AtomicU64::new(0),
            drain_rejected: AtomicU64::new(0),
        });
        let client = ServeClient {
            tx,
            shared: Arc::clone(&shared),
            in_dim: engine.in_dim(),
            out_dim: engine.out_dim(),
        };
        let loop_shared = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("quarl-serve".into())
            .spawn(move || serve_loop(&mut engine, &rx, cfg, &loop_shared, faults.as_deref()))
            .expect("spawn serve thread");
        (PolicyServer { handle, shared }, client)
    }

    /// Queries bounced by admission control so far (live counter; the
    /// final figure is also in the shutdown report).
    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Flip the server to Draining without waiting for it to exit: new
    /// queries fail fast with [`QueryError::Draining`]; the serve loop
    /// flushes what is already queued under the [`ServeConfig::drain`]
    /// deadline. Idempotent.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Drain and stop the server, then return its measurements.
    /// Completes even while [`ServeClient`] clones are still alive:
    /// already-queued requests are flushed (up to the drain deadline),
    /// everything later is rejected with [`QueryError::Draining`].
    pub fn shutdown(self) -> ServeReport {
        self.begin_drain();
        let mut report = self.handle.join().expect("serve thread panicked");
        report.rejected = self.shared.rejected.load(Ordering::Relaxed);
        // Client-side drain bounces join the loop-side flush rejects.
        report.drain_rejected += self.shared.drain_rejected.load(Ordering::Relaxed);
        report
    }
}

/// What one `collect_batch` call produced.
enum Collect {
    /// A non-empty batch is ready to dispatch.
    Ready,
    /// The drain flag flipped while waiting for a first request.
    Drain,
    /// Every client hung up; the queue can never refill.
    Disconnected,
}

/// Granularity at which the idle wait re-checks the drain flag. Coarse
/// enough to stay off the profile, fine enough that `shutdown` on an
/// idle server returns promptly.
const DRAIN_POLL: Duration = Duration::from_millis(5);

/// Collect one batch: wait for the first request (re-checking the drain
/// flag every [`DRAIN_POLL`]), then take everything that arrives within
/// `window` of dequeuing it (never past `max_batch`).
fn collect_batch(
    rx: &Receiver<Request>,
    max_batch: usize,
    window: Duration,
    batch: &mut Vec<Request>,
    draining: &AtomicBool,
) -> Collect {
    batch.clear();
    let first = loop {
        if draining.load(Ordering::SeqCst) {
            return Collect::Drain;
        }
        match rx.recv_timeout(DRAIN_POLL) {
            Ok(r) => break r,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return Collect::Disconnected,
        }
    };
    let deadline = Instant::now() + window;
    batch.push(first);
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => batch.push(r),
            Err(RecvTimeoutError::Timeout) => break,
            // Remaining senders gone; serve what we already hold, the
            // next collect_batch call reports the disconnect.
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Collect::Ready
}

/// Reusable per-batch row scratch (input tile + output tile).
struct Scratch {
    xs: Vec<f32>,
    out: Vec<f32>,
}

/// Counters and histograms the loop accumulates into the final report.
struct LoopStats {
    latency: LatencyHist,
    batches: BatchHist,
    queries: u64,
    slow_batches: u64,
    drain_rejected: u64,
    started: Option<Instant>,
}

/// Dispatch one collected batch through the engine and reply to every
/// request in it. Times the dispatch (including any fault-injected
/// stall) against the straggler deadline.
fn dispatch<E: Engine>(
    engine: &mut E,
    batch: &mut Vec<Request>,
    scratch: &mut Scratch,
    out_dim: usize,
    stats: &mut LoopStats,
    slow_deadline: Duration,
    faults: Option<&FaultPlan>,
) {
    stats.started.get_or_insert_with(Instant::now);
    let b = batch.len();
    let t0 = Instant::now();
    if let Some(plan) = faults {
        if let Some(stall) = plan.on_batch() {
            std::thread::sleep(stall);
        }
    }
    scratch.xs.clear();
    for req in batch.iter() {
        scratch.xs.extend_from_slice(&req.obs);
    }
    scratch.out.clear();
    scratch.out.resize(b * out_dim, 0.0);
    match engine.forward_batch(&scratch.xs, b, &mut scratch.out) {
        Ok(()) => {
            for (i, req) in batch.drain(..).enumerate() {
                let row = scratch.out[i * out_dim..(i + 1) * out_dim].to_vec();
                stats.latency.record(req.enqueued.elapsed());
                stats.queries += 1;
                // A client that gave up is its own problem.
                let _ = req.reply.send(Ok(row));
            }
            stats.batches.record(b);
        }
        Err(e) => {
            let msg = e.to_string();
            for req in batch.drain(..) {
                let _ = req.reply.send(Err(QueryError::Engine(msg.clone())));
            }
        }
    }
    if slow_deadline > Duration::ZERO && t0.elapsed() > slow_deadline {
        stats.slow_batches += 1;
    }
}

/// Drain phase: flush already-queued requests in full batches with no
/// window waits until the queue empties or the drain deadline passes,
/// then reject whatever remains with [`QueryError::Draining`].
#[allow(clippy::too_many_arguments)]
fn drain_queue<E: Engine>(
    engine: &mut E,
    rx: &Receiver<Request>,
    batch: &mut Vec<Request>,
    scratch: &mut Scratch,
    out_dim: usize,
    stats: &mut LoopStats,
    cfg: &ServeConfig,
    faults: Option<&FaultPlan>,
) {
    let max_batch = cfg.max_batch.max(1);
    let deadline = Instant::now() + cfg.drain;
    batch.clear();
    while Instant::now() < deadline {
        match rx.try_recv() {
            Ok(r) => {
                batch.push(r);
                if batch.len() >= max_batch {
                    dispatch(engine, batch, scratch, out_dim, stats, cfg.slow_batch, faults);
                }
            }
            Err(TryRecvError::Empty) => {
                if batch.is_empty() {
                    return; // queue flushed clean
                }
                dispatch(engine, batch, scratch, out_dim, stats, cfg.slow_batch, faults);
            }
            Err(TryRecvError::Disconnected) => {
                if !batch.is_empty() {
                    dispatch(engine, batch, scratch, out_dim, stats, cfg.slow_batch, faults);
                }
                return;
            }
        }
    }
    // Past the deadline: bounce the partial batch and the still-queued
    // remainder instead of wedging on a slow engine.
    for req in batch.drain(..) {
        let _ = req.reply.send(Err(QueryError::Draining));
        stats.drain_rejected += 1;
    }
    while let Ok(req) = rx.try_recv() {
        let _ = req.reply.send(Err(QueryError::Draining));
        stats.drain_rejected += 1;
    }
}

fn serve_loop<E: Engine>(
    engine: &mut E,
    rx: &Receiver<Request>,
    cfg: ServeConfig,
    shared: &ServeShared,
    faults: Option<&FaultPlan>,
) -> ServeReport {
    let max_batch = cfg.max_batch.max(1);
    let in_dim = engine.in_dim();
    let out_dim = engine.out_dim();
    let mut stats = LoopStats {
        latency: LatencyHist::new(),
        batches: BatchHist::new(max_batch),
        queries: 0,
        slow_batches: 0,
        drain_rejected: 0,
        started: None,
    };
    let mut batch: Vec<Request> = Vec::with_capacity(max_batch);
    let mut scratch = Scratch {
        xs: Vec::with_capacity(max_batch * in_dim),
        out: Vec::with_capacity(max_batch * out_dim),
    };

    loop {
        match collect_batch(rx, max_batch, cfg.window, &mut batch, &shared.draining) {
            Collect::Disconnected => break,
            Collect::Ready => {
                dispatch(engine, &mut batch, &mut scratch, out_dim, &mut stats, cfg.slow_batch, faults);
            }
            Collect::Drain => {
                drain_queue(engine, rx, &mut batch, &mut scratch, out_dim, &mut stats, &cfg, faults);
                break;
            }
        }
    }

    let wall_secs = stats.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
    ServeReport {
        queries: stats.queries,
        rejected: 0,
        latency: stats.latency,
        batches: stats.batches,
        wall_secs,
        slow_batches: stats.slow_batches,
        drain_rejected: stats.drain_rejected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Result as CrateResult;
    use crate::inference::engine_f32::test_fixtures::mlp_params;
    use crate::inference::{engine_for, EngineF32};
    use crate::quant::Precision;
    use crate::rng::Pcg32;

    fn obs_for(i: usize, in_dim: usize) -> Vec<f32> {
        let mut rng = Pcg32::new(0xC0FFEE ^ i as u64, 11);
        (0..in_dim).map(|_| rng.uniform_range(-1.0, 1.0)).collect()
    }

    #[test]
    fn served_logits_match_a_direct_engine_call_bit_for_bit() {
        let dims = [8, 32, 32, 4];
        let params = mlp_params(&dims, 42);
        for precision in [Precision::Fp32, Precision::Int(8), Precision::Int(4)] {
            let engine = engine_for(&params, precision).unwrap();
            let (server, client) = PolicyServer::spawn(engine, ServeConfig::default());
            let mut direct = engine_for(&params, precision).unwrap();
            for i in 0..16 {
                let obs = obs_for(i, dims[0]);
                let served = client.query(&obs).unwrap();
                let mut want = vec![0.0f32; dims[3]];
                direct.forward(&obs, &mut want).unwrap();
                assert_eq!(served, want, "row {i} diverged at {precision:?}");
            }
            drop(client);
            let report = server.shutdown();
            assert_eq!(report.queries, 16);
            assert_eq!(report.rejected, 0);
            assert_eq!(report.latency.count(), 16);
        }
    }

    #[test]
    fn concurrent_queries_coalesce_into_one_batch() {
        // A wide-open window and exactly max_batch concurrent clients:
        // the batch must fill and dispatch as ONE forward_batch call
        // (the window alone would hold it for 5 s — the test finishing
        // quickly is itself evidence the size trigger fired).
        let dims = [8, 16, 4];
        let params = mlp_params(&dims, 7);
        let engine = EngineF32::from_params(&params).unwrap();
        let cfg = ServeConfig {
            max_batch: 4,
            window: Duration::from_secs(5),
            queue_capacity: 16,
            ..ServeConfig::default()
        };
        let (server, client) = PolicyServer::spawn(engine, cfg);
        let joins: Vec<_> = (0..4)
            .map(|i| {
                let c = client.clone();
                let obs = obs_for(i, dims[0]);
                std::thread::spawn(move || c.query(&obs).unwrap())
            })
            .collect();
        for j in joins {
            assert_eq!(j.join().unwrap().len(), dims[2]);
        }
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.queries, 4);
        assert_eq!(report.batches.batches(), 1, "expected one coalesced batch");
        assert_eq!(report.batches.max_seen(), 4);
        assert!((report.batches.mean() - 4.0).abs() < 1e-12);
    }

    /// Engine stub whose forward_batch parks on a gate: it announces
    /// entry on `entered` and blocks until the test sends one `release`
    /// token, so the test can hold the server busy for as long as it
    /// needs to fill the request queue deterministically (no timing).
    struct GatedEngine {
        dims: (usize, usize),
        entered: std::sync::mpsc::Sender<()>,
        release: Receiver<()>,
    }

    impl Engine for GatedEngine {
        fn precision(&self) -> Precision {
            Precision::Fp32
        }
        fn forward(&mut self, _x: &[f32], out: &mut [f32]) -> CrateResult<()> {
            out.fill(0.0);
            Ok(())
        }
        fn forward_batch(&mut self, _xs: &[f32], batch: usize, out: &mut [f32]) -> CrateResult<()> {
            let _ = self.entered.send(());
            let _ = self.release.recv();
            out[..batch * self.dims.1].fill(0.0);
            Ok(())
        }
        fn memory_bytes(&self) -> usize {
            0
        }
        fn in_dim(&self) -> usize {
            self.dims.0
        }
        fn out_dim(&self) -> usize {
            self.dims.1
        }
    }

    #[test]
    fn full_queue_rejects_instead_of_blocking() {
        let cfg = ServeConfig {
            max_batch: 1,
            window: Duration::ZERO,
            queue_capacity: 1,
            ..ServeConfig::default()
        };
        let (entered_tx, entered_rx) = std::sync::mpsc::channel();
        let (release_tx, release_rx) = std::sync::mpsc::channel();
        let engine = GatedEngine { dims: (4, 2), entered: entered_tx, release: release_rx };
        let (server, client) = PolicyServer::spawn(engine, cfg);
        let obs = vec![0.0f32; 4];
        // First query occupies the engine (wait until it is inside
        // forward_batch, parked on the gate — the queue is empty again).
        let c0 = client.clone();
        let o0 = obs.clone();
        let first = std::thread::spawn(move || c0.query(&o0));
        entered_rx.recv().expect("engine never entered forward_batch");
        // Fill the capacity-1 queue by submitting a raw request directly
        // (ServeClient::query would block on its reply); once try_send
        // succeeds the queue is provably full while the engine is held.
        let (filler_tx, filler_rx) = sync_channel(1);
        let filler = Request {
            obs: obs.clone(),
            enqueued: Instant::now(),
            reply: filler_tx,
        };
        client.tx.try_send(filler).expect("filler must occupy the empty queue slot");
        // Every burst submission now bounces off admission control.
        let mut overloaded = 0;
        for _ in 0..8 {
            match client.query(&obs) {
                Err(QueryError::Overloaded) => overloaded += 1,
                Ok(_) => panic!("query accepted while the queue was provably full"),
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(overloaded, 8, "full queue must trip admission control every time");
        // Release the engine for the first query's batch and the filler's.
        release_tx.send(()).unwrap();
        release_tx.send(()).unwrap();
        assert!(first.join().unwrap().is_ok());
        assert!(filler_rx.recv().unwrap().is_ok());
        drop(client);
        let report = server.shutdown();
        // The filler bypassed ServeClient, so only the burst counts as rejected.
        assert_eq!(report.rejected, overloaded as u64);
        assert_eq!(report.queries, 2);
    }

    #[test]
    fn shape_mismatch_is_rejected_client_side() {
        let dims = [8, 16, 4];
        let params = mlp_params(&dims, 3);
        let engine = EngineF32::from_params(&params).unwrap();
        let (server, client) = PolicyServer::spawn(engine, ServeConfig::default());
        assert_eq!(
            client.query(&[0.0; 5]).unwrap_err(),
            QueryError::Shape { got: 5, want: 8 }
        );
        assert_eq!(client.out_dim(), 4);
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.queries, 0);
        assert_eq!(report.wall_secs, 0.0, "no query ever started the wall clock");
    }

    /// Regression for the shutdown wedge: `shutdown` used to block until
    /// every client clone was dropped. It must now return with clients
    /// deliberately retained, and late queries must bounce with
    /// `Draining` rather than hang.
    #[test]
    fn shutdown_returns_with_a_retained_client_and_bounces_late_queries() {
        let dims = [8, 16, 4];
        let params = mlp_params(&dims, 9);
        let engine = EngineF32::from_params(&params).unwrap();
        let cfg = ServeConfig { drain: Duration::from_millis(200), ..ServeConfig::default() };
        let (server, client) = PolicyServer::spawn(engine, cfg);
        assert!(client.query(&obs_for(0, dims[0])).is_ok());
        server.begin_drain();
        // The drain flag bounces new submissions client-side.
        assert_eq!(client.query(&obs_for(1, dims[0])).unwrap_err(), QueryError::Draining);
        // `client` is alive across the join — the old code would never return.
        let report = server.shutdown();
        assert_eq!(report.queries, 1);
        assert_eq!(report.drain_rejected, 1, "the late query counts as drain-rejected");
        // After exit the channel is gone entirely.
        assert_eq!(client.query(&obs_for(2, dims[0])).unwrap_err(), QueryError::Draining);
    }

    /// Draining flushes what is already queued (no window waits) before
    /// the deadline, and a `Duration::ZERO` drain budget rejects queued
    /// work with `Draining` instead of wedging on a slow engine.
    #[test]
    fn drain_flushes_queued_requests_then_deadline_rejects_the_rest() {
        // Flush case: gated engine holds the first batch; two raw
        // requests queue behind it; drain must serve them.
        let cfg = ServeConfig {
            max_batch: 1,
            window: Duration::ZERO,
            queue_capacity: 4,
            drain: Duration::from_secs(5),
            ..ServeConfig::default()
        };
        let (entered_tx, entered_rx) = std::sync::mpsc::channel();
        let (release_tx, release_rx) = std::sync::mpsc::channel();
        let engine = GatedEngine { dims: (4, 2), entered: entered_tx, release: release_rx };
        let (server, client) = PolicyServer::spawn(engine, cfg);
        let obs = vec![0.0f32; 4];
        let c0 = client.clone();
        let o0 = obs.clone();
        let first = std::thread::spawn(move || c0.query(&o0));
        entered_rx.recv().expect("engine never entered forward_batch");
        let fillers: Vec<_> = (0..2)
            .map(|_| {
                let (ftx, frx) = sync_channel(1);
                let req = Request { obs: obs.clone(), enqueued: Instant::now(), reply: ftx };
                client.tx.try_send(req).expect("queue slot");
                frx
            })
            .collect();
        server.begin_drain();
        // Release every batch: the in-flight one plus one per queued filler.
        for _ in 0..3 {
            release_tx.send(()).unwrap();
        }
        assert!(first.join().unwrap().is_ok());
        for frx in &fillers {
            assert!(frx.recv().unwrap().is_ok(), "queued request must be flushed, not rejected");
        }
        let report = server.shutdown();
        assert_eq!(report.queries, 3);
        assert_eq!(report.drain_rejected, 0);

        // Deadline case: same setup, zero drain budget — queued requests
        // are bounced the moment the loop reaches the drain phase.
        let cfg = ServeConfig {
            max_batch: 1,
            window: Duration::ZERO,
            queue_capacity: 4,
            drain: Duration::ZERO,
            ..ServeConfig::default()
        };
        let (entered_tx, entered_rx) = std::sync::mpsc::channel();
        let (release_tx, release_rx) = std::sync::mpsc::channel();
        let engine = GatedEngine { dims: (4, 2), entered: entered_tx, release: release_rx };
        let (server, client) = PolicyServer::spawn(engine, cfg);
        let c0 = client.clone();
        let o0 = obs.clone();
        let first = std::thread::spawn(move || c0.query(&o0));
        entered_rx.recv().expect("engine never entered forward_batch");
        let fillers: Vec<_> = (0..2)
            .map(|_| {
                let (ftx, frx) = sync_channel(1);
                let req = Request { obs: obs.clone(), enqueued: Instant::now(), reply: ftx };
                client.tx.try_send(req).expect("queue slot");
                frx
            })
            .collect();
        server.begin_drain();
        release_tx.send(()).unwrap(); // only the in-flight batch completes
        assert!(first.join().unwrap().is_ok());
        for frx in &fillers {
            assert_eq!(
                frx.recv().unwrap().unwrap_err(),
                QueryError::Draining,
                "zero drain budget must reject queued work"
            );
        }
        let report = server.shutdown();
        assert_eq!(report.queries, 1);
        assert_eq!(report.drain_rejected, 2);
        drop(release_tx);
    }

    /// A scripted `slow_batch` stall pushes the dispatch past the
    /// straggler deadline and is tallied — deterministically, because
    /// the stall is injected, not load-dependent.
    #[test]
    fn scripted_slow_batch_trips_the_straggler_counter() {
        let dims = [8, 16, 4];
        let params = mlp_params(&dims, 21);
        let engine = EngineF32::from_params(&params).unwrap();
        let cfg = ServeConfig {
            slow_batch: Duration::from_millis(5),
            ..ServeConfig::default()
        };
        let plan = Arc::new(FaultPlan::new(7).slow_batch(2, 30));
        let (server, client) = PolicyServer::spawn_faulted(engine, cfg, Some(Arc::clone(&plan)));
        for i in 0..3 {
            assert!(client.query(&obs_for(i, dims[0])).is_ok());
        }
        drop(client);
        let report = server.shutdown();
        assert_eq!(report.queries, 3);
        assert_eq!(report.slow_batches, 1, "exactly the stalled batch is a straggler");
        assert_eq!(plan.count(crate::faults::FaultKind::SlowBatch), 1);
    }
}

//! Serving telemetry: a log-linear latency histogram (p50/p99 without
//! storing per-query samples) and the coalesced batch-size
//! distribution, plus the [`ServeReport`] the server hands back at
//! shutdown.

use std::time::Duration;

/// Log-linear (HDR-style) latency histogram in nanoseconds: buckets are
/// power-of-two octaves subdivided into 4 sub-buckets (2 significant
/// bits), so any recorded value lands in a bucket whose lower bound is
/// within 25% of it. O(1) memory for any query count — a serving
/// front-end cannot keep every sample — at a resolution that is plenty
/// for p50/p99 reporting.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    /// Bucket index: values 0..4 map to themselves; above that,
    /// `4 * (octave - 1) + 2-bit mantissa` (octave = floor(log2 v)).
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

/// 63 octaves x 4 sub-buckets + the 4 identity slots (indices overlap
/// below octave 2, so 252 covers the full u64 range).
const N_BUCKETS: usize = 252;

fn bucket_index(ns: u64) -> usize {
    let v = ns.max(1);
    let octave = 63 - v.leading_zeros() as u64; // floor(log2 v)
    if octave < 2 {
        v as usize
    } else {
        (4 * (octave - 1) + ((v >> (octave - 2)) & 3)) as usize
    }
}

/// Lower bound (ns) of bucket `idx` — the value `percentile_ns` reports.
fn bucket_floor(idx: usize) -> u64 {
    if idx < 4 {
        idx as u64
    } else {
        let octave = (idx as u64) / 4 + 1;
        let sub = (idx as u64) % 4;
        (4 + sub) << (octave - 2)
    }
}

impl Default for LatencyHist {
    fn default() -> LatencyHist {
        LatencyHist::new()
    }
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        LatencyHist {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    pub fn record(&mut self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.buckets[bucket_index(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Latency at quantile `q` in [0, 1]: the lower bound of the bucket
    /// where the cumulative count crosses `ceil(q * count)` (within 25%
    /// of the true sample quantile by construction). The top quantile
    /// (`q >= 1`) is the exact recorded maximum, not a bucket floor.
    /// 0 when empty.
    pub fn percentile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if q >= 1.0 {
            return self.max_ns;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_floor(idx).max(self.min_ns).min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn p50_us(&self) -> f64 {
        self.percentile_ns(0.50) as f64 / 1_000.0
    }

    pub fn p99_us(&self) -> f64 {
        self.percentile_ns(0.99) as f64 / 1_000.0
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64 / 1_000.0
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_ns as f64 / 1_000.0
    }

    /// Non-empty `(bucket_floor_ns, count)` pairs, for report exports.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_floor(i), c))
            .collect()
    }
}

/// How many queries each `forward_batch` call coalesced: counts indexed
/// by batch size (index `b - 1` holds the number of batches of size
/// `b`).
#[derive(Debug, Clone, Default)]
pub struct BatchHist {
    counts: Vec<u64>,
}

impl BatchHist {
    pub fn new(max_batch: usize) -> BatchHist {
        BatchHist { counts: vec![0; max_batch.max(1)] }
    }

    pub fn record(&mut self, batch: usize) {
        if batch == 0 {
            return;
        }
        if batch > self.counts.len() {
            self.counts.resize(batch, 0);
        }
        self.counts[batch - 1] += 1;
    }

    /// Batches recorded.
    pub fn batches(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Queries across all batches.
    pub fn queries(&self) -> u64 {
        self.counts.iter().enumerate().map(|(i, &c)| (i as u64 + 1) * c).sum()
    }

    pub fn mean(&self) -> f64 {
        let b = self.batches();
        if b == 0 {
            0.0
        } else {
            self.queries() as f64 / b as f64
        }
    }

    pub fn max_seen(&self) -> usize {
        self.counts.iter().rposition(|&c| c > 0).map(|i| i + 1).unwrap_or(0)
    }

    /// Per-size counts (index `b - 1` = batches of size `b`).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }
}

/// Everything a server run measured, returned by
/// [`crate::serve::PolicyServer::shutdown`].
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Queries answered (admission-rejected queries excluded).
    pub queries: u64,
    /// Queries bounced by admission control (the bounded request queue
    /// was full at submission time).
    pub rejected: u64,
    /// Enqueue-to-reply latency of answered queries.
    pub latency: LatencyHist,
    /// Coalesced batch-size distribution.
    pub batches: BatchHist,
    /// Wall seconds from the first request to server exit.
    pub wall_secs: f64,
    /// Dispatched batches that ran past the configured slow-batch
    /// deadline (stragglers; 0 when detection is disabled).
    pub slow_batches: u64,
    /// Queries rejected because the server was draining: late
    /// submissions bounced client-side plus queued requests flushed out
    /// past the drain deadline.
    pub drain_rejected: u64,
}

impl ServeReport {
    /// Answered-query throughput over the measured wall window.
    pub fn qps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.queries as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_floor_is_within_25_percent_below_value() {
        for ns in [1u64, 3, 4, 7, 9, 100, 999, 1_000, 123_456, 10_000_000, u64::MAX / 2] {
            let f = bucket_floor(bucket_index(ns));
            assert!(f <= ns, "floor {f} > value {ns}");
            assert!(ns - f <= ns / 4, "floor {f} more than 25% below {ns}");
        }
        // indices are monotone in the value
        let mut last = 0;
        for ns in 1..10_000u64 {
            let idx = bucket_index(ns);
            assert!(idx >= last, "index regressed at {ns}");
            last = idx;
        }
    }

    #[test]
    fn percentiles_are_ordered_and_bracketed() {
        let mut h = LatencyHist::new();
        for us in 1..=1_000u64 {
            h.record_ns(us * 1_000);
        }
        assert_eq!(h.count(), 1_000);
        let (p50, p90, p99) =
            (h.percentile_ns(0.50), h.percentile_ns(0.90), h.percentile_ns(0.99));
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        // within the histogram's 25% bucket resolution of the truth
        assert!((375_000..=500_000).contains(&p50), "p50 {p50}");
        assert!(p99 <= h.percentile_ns(1.0));
        assert_eq!(h.percentile_ns(1.0), 1_000_000);
        assert!(h.mean_us() > 400.0 && h.mean_us() < 600.0);
    }

    #[test]
    fn empty_hist_is_all_zeros() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_ns(0.5), 0);
        assert_eq!(h.p99_us(), 0.0);
        assert_eq!(h.mean_us(), 0.0);
        assert!(h.nonzero_buckets().is_empty());
    }

    #[test]
    fn batch_hist_counts_mean_and_max() {
        let mut b = BatchHist::new(4);
        for size in [1, 1, 4, 2] {
            b.record(size);
        }
        assert_eq!(b.batches(), 4);
        assert_eq!(b.queries(), 8);
        assert!((b.mean() - 2.0).abs() < 1e-12);
        assert_eq!(b.max_seen(), 4);
        assert_eq!(b.counts(), &[2, 1, 0, 1]);
        b.record(6); // beyond the configured max: grows, never drops
        assert_eq!(b.max_seen(), 6);
        assert_eq!(b.queries(), 14);
    }
}

//! DDPG trainer (Lillicrap et al. 2015) for the continuous-control cells
//! of paper Table 2 (Walker2D/HalfCheetah/BipedalWalker/MountainCar-C).
//!
//! Rust owns exploration noise, uniform replay, and the polyak target
//! updates (a host-side lerp on the master copies); the XLA side owns
//! both actor and critic updates in one program call.

use std::cell::RefCell;

use crate::actorq::learner::HarnessConfig;
use crate::actorq::{ActorQConfig, ActorQLog, Exploration, LearnerHarness, ReturnLog};
use crate::algos::common::{load_programs, pad_obs, QuantSchedule, TrainedPolicy};
use crate::envs::api::Action;
use crate::envs::registry::make_env;
use crate::error::Result;
use crate::replay::{ReplayBuffer, Transition};
use crate::rng::Pcg32;
use crate::runtime::{ParamSet, Runtime};
use crate::sustain::Component;
use crate::tensor::Tensor;

pub use crate::algos::dqn::TrainLog;

#[derive(Debug, Clone)]
pub struct DdpgConfig {
    pub env_id: String,
    pub arch_key: Option<String>,
    pub total_steps: usize,
    pub buffer_size: usize,
    pub warmup: usize,
    pub train_freq: usize,
    pub lr_actor: f32,
    pub lr_critic: f32,
    pub gamma: f32,
    pub tau: f32,
    /// Gaussian exploration noise std (annealed linearly to 30%).
    pub noise_std: f32,
    pub quant: QuantSchedule,
    pub seed: u64,
    pub log_every: usize,
}

impl DdpgConfig {
    pub fn new(env_id: &str) -> Self {
        DdpgConfig {
            env_id: env_id.into(),
            arch_key: None,
            total_steps: 30_000,
            buffer_size: 50_000,
            warmup: 1_000,
            train_freq: 1,
            lr_actor: 1e-4,
            lr_critic: 1e-3,
            gamma: 0.99,
            tau: 0.005,
            noise_std: 0.2,
            quant: QuantSchedule::off(),
            seed: 0,
            log_every: 0,
        }
    }
}

/// Train a DDPG policy.
pub fn train(rt: &Runtime, cfg: &DdpgConfig) -> Result<(TrainedPolicy, TrainLog)> {
    let key = cfg.arch_key.clone().unwrap_or_else(|| format!("ddpg/{}", cfg.env_id));
    let (arch, act_prog, train_prog) = load_programs(rt, &key)?;
    let spec = &train_prog.spec;
    let na = spec.count("n_actor_params")?;
    let nc = spec.count("n_critic_params")?;
    let n_q = spec.n_qstate;
    let batch = spec.arch.train_batch;
    let act_batch = act_prog.spec.arch.act_batch;
    let act_dim = spec.arch.act_dim;

    let mut root = Pcg32::new(cfg.seed, 29);
    let mut env_rng = root.split(1);
    let mut noise_rng = root.split(2);
    let mut replay_rng = root.split(3);
    let mut init_rng = root.split(4);

    let mut env = make_env(&cfg.env_id)?;
    let obs_dim = env.obs_dim();

    let actor = ParamSet::init(&spec.inputs[..na], &mut init_rng);
    let critic = ParamSet::init(&spec.inputs[na..na + nc], &mut init_rng);

    // Train inputs: actor, critic, t_actor, t_critic, m_a, v_a, m_c, v_c,
    // qstate, obs, act, rew, nobs, done, hyper
    let mut train_in: Vec<Tensor> = Vec::new();
    train_in.extend(actor.tensors.iter().cloned());
    train_in.extend(critic.tensors.iter().cloned());
    train_in.extend(actor.tensors.iter().cloned()); // target actor
    train_in.extend(critic.tensors.iter().cloned()); // target critic
    for t in actor.tensors.iter() {
        train_in.push(Tensor::zeros(t.shape().to_vec()));
    }
    for t in actor.tensors.iter() {
        train_in.push(Tensor::zeros(t.shape().to_vec()));
    }
    for t in critic.tensors.iter() {
        train_in.push(Tensor::zeros(t.shape().to_vec()));
    }
    for t in critic.tensors.iter() {
        train_in.push(Tensor::zeros(t.shape().to_vec()));
    }
    let i_qstate = 4 * na + 4 * nc;
    debug_assert_eq!(train_in.len(), i_qstate);
    train_in.push(Tensor::zeros(vec![n_q, 2]));
    train_in.push(Tensor::zeros(vec![batch, obs_dim]));
    train_in.push(Tensor::zeros(vec![batch, act_dim]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::zeros(vec![batch, obs_dim]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::vec1(&[cfg.lr_actor, cfg.lr_critic, cfg.gamma, 0.0, 0.0, 0.0, 1.0]));
    let i_obs = i_qstate + 1;
    let i_hyper = i_obs + 5;

    let mut buf = ReplayBuffer::new(cfg.buffer_size, obs_dim, act_dim);
    let mut obs = vec![0.0f32; obs_dim];
    let mut next_obs = vec![0.0f32; obs_dim];
    env.reset(&mut env_rng, &mut obs);

    let mut log = TrainLog::default();
    let t_start = std::time::Instant::now();
    let mut ep_return = 0.0f32;
    let mut recent: Vec<f32> = Vec::new();
    let mut adam_t = 0.0f32;
    let mut action = vec![0.0f32; act_dim];

    let quant_bits = cfg.quant.bits as f32;
    let quant_delay = cfg.quant.delay as f32;

    for step in 0..cfg.total_steps {
        // --- act + exploration noise ---
        if step < cfg.warmup {
            for a in action.iter_mut() {
                *a = noise_rng.uniform_range(-1.0, 1.0);
            }
        } else {
            let mut act_in: Vec<Tensor> = train_in[..na].to_vec();
            act_in.push(train_in[i_qstate].clone());
            act_in.push(pad_obs(&obs, act_batch));
            act_in.push(Tensor::vec1(&[quant_bits, step as f32, quant_delay]));
            let out = act_prog.run(&act_in)?;
            let frac = 1.0 - 0.7 * (step as f32 / cfg.total_steps as f32);
            let std = cfg.noise_std * frac;
            for (a, &mu) in action.iter_mut().zip(out[0].row(0)) {
                *a = (mu + noise_rng.normal_ms(0.0, std)).clamp(-1.0, 1.0);
            }
        }

        // --- env step ---
        let s = env.step(&Action::Continuous(action.clone()), &mut env_rng, &mut next_obs);
        ep_return += s.reward;
        buf.push(Transition {
            obs: &obs,
            action: &action,
            reward: s.reward,
            next_obs: &next_obs,
            done: s.done,
        });
        if s.done {
            log.episodes += 1;
            recent.push(ep_return);
            if cfg.log_every > 0 {
                log.returns.push((step, ep_return));
            }
            ep_return = 0.0;
            env.reset(&mut env_rng, &mut obs);
        } else {
            obs.copy_from_slice(&next_obs);
        }

        // --- learn ---
        if step >= cfg.warmup && step % cfg.train_freq == 0 && buf.len() >= batch {
            let b = buf.sample(batch, &mut replay_rng);
            adam_t += 1.0;
            train_in[i_obs] = b.obs;
            // replay flattens act_dim==1 to (B,); the program wants (B, A)
            train_in[i_obs + 1] = b.actions.reshape(vec![batch, act_dim])?;
            train_in[i_obs + 2] = b.rewards;
            train_in[i_obs + 3] = b.next_obs;
            train_in[i_obs + 4] = b.dones;
            train_in[i_hyper] = Tensor::vec1(&[
                cfg.lr_actor, cfg.lr_critic, cfg.gamma, quant_bits, step as f32, quant_delay,
                adam_t,
            ]);
            let t0 = std::time::Instant::now();
            let out = train_prog.run(&train_in)?;
            log.train_exec_secs += t0.elapsed().as_secs_f64();
            // outputs: actor, critic, m_a, v_a, m_c, v_c, qstate, closs, aloss
            let n_all = na + nc;
            for i in 0..n_all {
                train_in[i] = out[i].clone(); // actor+critic
            }
            for i in 0..(2 * na + 2 * nc) {
                train_in[2 * n_all + i] = out[n_all + i].clone(); // opt state
            }
            train_in[i_qstate] = out[3 * na + 3 * nc].clone();

            // Polyak target update host-side.
            let tau = cfg.tau;
            for i in 0..n_all {
                let (online, target) = {
                    let (a, b) = train_in.split_at_mut(n_all + i);
                    (&a[i], &mut b[0])
                };
                for (t, o) in target.data_mut().iter_mut().zip(online.data()) {
                    *t = tau * o + (1.0 - tau) * *t;
                }
            }

            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                let closs = out[3 * na + 3 * nc + 1].data()[0];
                log.losses.push((step, closs));
            }
        }
    }

    let tail = &recent[recent.len().saturating_sub(20)..];
    log.final_return = if tail.is_empty() {
        ep_return
    } else {
        tail.iter().sum::<f32>() / tail.len() as f32
    };
    log.wall_secs = t_start.elapsed().as_secs_f64();

    let mut actor_out = actor;
    for i in 0..na {
        actor_out.tensors[i] = train_in[i].clone();
    }
    Ok((
        TrainedPolicy {
            algo: "ddpg".into(),
            env_id: cfg.env_id.clone(),
            arch,
            params: actor_out,
            qstate: train_in[i_qstate].clone(),
            quant: cfg.quant,
            steps: cfg.total_steps,
        },
        log,
    ))
}

/// Train a DDPG policy with the ActorQ actor-learner driver (paper §3).
///
/// Actor threads run a quantized copy of the *actor network only* on the
/// native engines (at any engine-supported [`crate::quant::Precision`])
/// — the critic never leaves the learner — with Gaussian exploration
/// and a [-1, 1] clamp matching [`train`]. The native head is linear
/// (no tanh squash), so the exploration clamp doubles as the action
/// bound, the same approximation the deployment engines make. Each
/// actor's vec-env sweep is a single batched `forward_batch` on its
/// engine copy (weight panels stream once per sweep, not once per env).
/// Pool setup, the drain + pacer loop, and the log assembly live in the
/// shared [`LearnerHarness`]; this driver contributes the DDPG
/// train-program closure.
pub fn train_actorq(
    rt: &Runtime,
    cfg: &DdpgConfig,
    acfg: &ActorQConfig,
) -> Result<(TrainedPolicy, ActorQLog)> {
    let key = cfg.arch_key.clone().unwrap_or_else(|| format!("ddpg/{}", cfg.env_id));
    let (arch, _act_prog, train_prog) = load_programs(rt, &key)?;
    let spec = &train_prog.spec;
    let na = spec.count("n_actor_params")?;
    let nc = spec.count("n_critic_params")?;
    let n_q = spec.n_qstate;
    let batch = spec.arch.train_batch;
    let act_dim = spec.arch.act_dim;

    let mut root = Pcg32::new(cfg.seed, 59);
    let mut replay_rng = root.split(1);
    let mut init_rng = root.split(2);

    let probe = make_env(&cfg.env_id)?;
    let obs_dim = probe.obs_dim();
    drop(probe);

    let actor = ParamSet::init(&spec.inputs[..na], &mut init_rng);
    let critic = ParamSet::init(&spec.inputs[na..na + nc], &mut init_rng);

    // Same slot layout as the synchronous driver: actor, critic, t_actor,
    // t_critic, m_a, v_a, m_c, v_c, qstate, obs, act, rew, nobs, done, hyper
    let mut train_in: Vec<Tensor> = Vec::new();
    train_in.extend(actor.tensors.iter().cloned());
    train_in.extend(critic.tensors.iter().cloned());
    train_in.extend(actor.tensors.iter().cloned()); // target actor
    train_in.extend(critic.tensors.iter().cloned()); // target critic
    for t in actor.tensors.iter() {
        train_in.push(Tensor::zeros(t.shape().to_vec()));
    }
    for t in actor.tensors.iter() {
        train_in.push(Tensor::zeros(t.shape().to_vec()));
    }
    for t in critic.tensors.iter() {
        train_in.push(Tensor::zeros(t.shape().to_vec()));
    }
    for t in critic.tensors.iter() {
        train_in.push(Tensor::zeros(t.shape().to_vec()));
    }
    let i_qstate = 4 * na + 4 * nc;
    debug_assert_eq!(train_in.len(), i_qstate);
    train_in.push(Tensor::zeros(vec![n_q, 2]));
    train_in.push(Tensor::zeros(vec![batch, obs_dim]));
    train_in.push(Tensor::zeros(vec![batch, act_dim]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::zeros(vec![batch, obs_dim]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::vec1(&[cfg.lr_actor, cfg.lr_critic, cfg.gamma, 0.0, 0.0, 0.0, 1.0]));
    let i_obs = i_qstate + 1;
    let i_hyper = i_obs + 5;

    // The harness owns pool setup, the drain + pacer loop, and the log
    // assembly; acfg.precision enters the stack exactly once, here.
    let horizon = (cfg.total_steps / acfg.n_actors.max(1)).max(1);
    let mut actor_pub = actor.clone();
    let harness = LearnerHarness::spawn(
        &actor_pub,
        &HarnessConfig {
            env_id: &cfg.env_id,
            seed: cfg.seed,
            total_steps: cfg.total_steps,
            warmup: cfg.warmup,
            train_freq: cfg.train_freq,
            log_every: cfg.log_every,
            exploration: Exploration::Gaussian {
                std: cfg.noise_std,
                horizon,
                warmup: (cfg.warmup / acfg.n_actors.max(1)).max(1),
            },
            returns: ReturnLog::PerEpisode,
            acfg,
            faults: None,
            ckpt: None,
            resume: None,
        },
    )?;
    let meter = harness.meter.clone();
    let broadcast = harness.broadcast.clone();

    // Both the push hook and the train closure touch the replay buffer;
    // the harness never runs them concurrently, so a RefCell suffices.
    let buf = RefCell::new(ReplayBuffer::new(cfg.buffer_size, obs_dim, act_dim));
    let mut adam_t = 0.0f32;
    let mut exec_secs = 0.0f64;
    let n_all = na + nc;

    let quant_bits = cfg.quant.bits as f32;
    let quant_delay = cfg.quant.delay as f32;

    let mut log = harness.run(
        |t| {
            buf.borrow_mut().push(Transition {
                obs: &t.obs,
                action: &t.action,
                reward: t.reward,
                next_obs: &t.next_obs,
                done: t.done,
            });
        },
        |step, publish| {
            let buf = buf.borrow();
            if buf.len() < batch {
                return Ok(None);
            }
            let b = buf.sample(batch, &mut replay_rng);
            adam_t += 1.0;
            train_in[i_obs] = b.obs;
            train_in[i_obs + 1] = b.actions.reshape(vec![batch, act_dim])?;
            train_in[i_obs + 2] = b.rewards;
            train_in[i_obs + 3] = b.next_obs;
            train_in[i_obs + 4] = b.dones;
            train_in[i_hyper] = Tensor::vec1(&[
                cfg.lr_actor, cfg.lr_critic, cfg.gamma, quant_bits, step as f32, quant_delay,
                adam_t,
            ]);
            let t0 = std::time::Instant::now();
            let out = {
                let _busy = meter.scope(Component::Learner);
                train_prog.run(&train_in)?
            };
            exec_secs += t0.elapsed().as_secs_f64();
            meter.add_steps(Component::Learner, 1);
            for i in 0..n_all {
                train_in[i] = out[i].clone(); // actor+critic
            }
            for i in 0..(2 * na + 2 * nc) {
                train_in[2 * n_all + i] = out[n_all + i].clone(); // opt state
            }
            train_in[i_qstate] = out[3 * na + 3 * nc].clone();

            // Polyak target update host-side.
            let tau = cfg.tau;
            for i in 0..n_all {
                let (online, target) = {
                    let (a, b) = train_in.split_at_mut(n_all + i);
                    (&a[i], &mut b[0])
                };
                for (t, o) in target.data_mut().iter_mut().zip(online.data()) {
                    *t = tau * o + (1.0 - tau) * *t;
                }
            }

            if publish {
                for i in 0..na {
                    actor_pub.tensors[i] = train_in[i].clone();
                }
                {
                    let _busy = meter.scope(Component::Broadcast);
                    broadcast.publish(&actor_pub)?;
                }
                meter.add_steps(Component::Broadcast, 1);
            }
            Ok(Some(out[3 * na + 3 * nc + 1].data()[0]))
        },
    )?;
    log.train_exec_secs = exec_secs;

    for i in 0..na {
        actor_pub.tensors[i] = train_in[i].clone();
    }
    Ok((
        TrainedPolicy {
            algo: "ddpg".into(),
            env_id: cfg.env_id.clone(),
            arch,
            params: actor_pub,
            qstate: train_in[i_qstate].clone(),
            quant: cfg.quant,
            steps: log.env_steps,
        },
        log,
    ))
}

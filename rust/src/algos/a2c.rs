//! A2C trainer (Mnih et al. 2016): synchronous n-step advantage
//! actor-critic over a vectorized environment, driving the AOT programs.
//!
//! Rust owns rollout collection, categorical sampling, GAE, and QAT
//! bookkeeping; the XLA side owns forward/backward/Adam/fake-quant.

use crate::algos::common::{load_programs, QuantSchedule, TrainedPolicy};
use crate::envs::api::Action;
use crate::envs::registry::make_env;
use crate::envs::vec_env::VecEnv;
use crate::error::Result;
use crate::replay::RolloutBuffer;
use crate::rng::Pcg32;
use crate::runtime::{ParamSet, Runtime};
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct A2cConfig {
    pub env_id: String,
    pub arch_key: Option<String>,
    /// Total environment steps (across all envs).
    pub total_steps: usize,
    pub n_envs: usize,
    pub n_steps: usize,
    pub lr: f32,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub vf_coef: f32,
    pub ent_coef: f32,
    pub quant: QuantSchedule,
    pub seed: u64,
    pub log_every: usize,
    /// Optional layer-norm variant key suffix (Fig 1 baseline): uses
    /// `<algo>/<env>/ln` in the arch map.
    pub layer_norm: bool,
}

impl A2cConfig {
    pub fn new(env_id: &str) -> Self {
        A2cConfig {
            env_id: env_id.into(),
            arch_key: None,
            total_steps: 150_000,
            n_envs: 8,
            n_steps: 16,
            lr: 7e-4,
            gamma: 0.99,
            gae_lambda: 0.95,
            vf_coef: 0.5,
            ent_coef: 0.01,
            quant: QuantSchedule::off(),
            seed: 0,
            log_every: 0,
            layer_norm: false,
        }
    }
}

pub use crate::algos::dqn::TrainLog;

/// Shared rollout machinery for A2C and PPO (they differ only in the
/// train-program call). Returns the trained policy + log.
pub(crate) fn train_onpolicy(
    rt: &Runtime,
    algo: &str,
    env_id: &str,
    arch_key: Option<String>,
    layer_norm: bool,
    total_steps: usize,
    n_envs: usize,
    n_steps: usize,
    gamma: f32,
    lam: f32,
    quant: QuantSchedule,
    seed: u64,
    log_every: usize,
    mut make_hyper: impl FnMut(usize, f32) -> Vec<f32>,
    ppo_epochs: usize,
    probe_every: usize,
    probe: &mut dyn FnMut(usize, &[Tensor], &Tensor),
) -> Result<(TrainedPolicy, TrainLog)> {
    let key = arch_key.unwrap_or_else(|| {
        if layer_norm {
            format!("{algo}/{env_id}/ln")
        } else {
            format!("{algo}/{env_id}")
        }
    });
    let (arch, act_prog, train_prog) = load_programs(rt, &key)?;
    let spec = &train_prog.spec;
    let n_pi = spec.count("n_policy_params")?;
    let n_vf = spec.count("n_value_params")?;
    let n_all = n_pi + n_vf;
    let n_q = spec.n_qstate;
    let batch = spec.arch.train_batch;
    assert_eq!(batch, n_envs * n_steps, "manifest batch must equal rollout size");
    let n_actions = spec.arch.act_dim;

    let mut root = Pcg32::new(seed, 23);
    let mut sample_rng = root.split(1);
    let mut init_rng = root.split(2);

    let mut venv = VecEnv::new(n_envs, seed ^ 0x5eed, || make_env(env_id).expect("env"));
    let obs_dim = venv.obs_dim();

    let mut params = ParamSet::init(&spec.inputs[..n_all], &mut init_rng);
    let zeros = params.zeros_like();

    // Train inputs: params, m, v, qstate, obs, actions, returns, adv,
    // [old_logp], hyper
    let mut train_in: Vec<Tensor> = Vec::new();
    train_in.extend(params.tensors.iter().cloned());
    train_in.extend(zeros.tensors.iter().cloned());
    train_in.extend(zeros.tensors.iter().cloned());
    train_in.push(Tensor::zeros(vec![n_q, 2]));
    let i_qstate = 3 * n_all;
    let i_batch0 = i_qstate + 1;
    let extra = spec.inputs.len() - i_batch0; // obs..hyper count
    for k in 0..extra {
        train_in.push(Tensor::zeros(spec.inputs[i_batch0 + k].shape.clone()));
    }
    let i_hyper = spec.inputs.len() - 1;
    let has_old_logp = spec.input_index("old_logp").is_ok();

    let mut rollout = RolloutBuffer::new(n_steps, n_envs, obs_dim);
    let mut log = TrainLog::default();
    let t_start = std::time::Instant::now();
    let mut adam_t = 0.0f32;
    let mut step = 0usize;

    let quant_bits = quant.bits as f32;
    let quant_delay = quant.delay as f32;

    let mut actions = vec![0usize; n_envs];
    let mut logps = vec![0.0f32; n_envs];
    // Reusable probability buffer: the whole-batch act program already
    // amortizes the forward over n_envs; the per-row softmax must not
    // re-allocate in the selection loop either.
    let mut probs = vec![0.0f32; n_actions];

    while step < total_steps {
        rollout.clear();
        let mut act_in: Vec<Tensor> = train_in[..n_all].to_vec();
        act_in.push(train_in[i_qstate].clone());
        act_in.push(Tensor::zeros(vec![n_envs, obs_dim]));
        act_in.push(Tensor::vec1(&[quant_bits, step as f32, quant_delay]));
        let i_act_obs = act_in.len() - 2;

        for _ in 0..n_steps {
            let obs_snapshot = venv.obs().to_vec();
            act_in[i_act_obs] = Tensor::new(vec![n_envs, obs_dim], obs_snapshot.clone())?;
            let out = act_prog.run(&act_in)?;
            let logits = &out[0];
            let values = &out[1];
            for e in 0..n_envs {
                let row = logits.row(e);
                crate::tensor::softmax_into(row, &mut probs);
                let a = sample_rng.categorical(&probs);
                actions[e] = a;
                logps[e] = probs[a].max(1e-12).ln();
            }
            let acts: Vec<Action> = actions.iter().map(|&a| Action::Discrete(a)).collect();
            let results = venv.step(&acts);
            let rewards: Vec<f32> = results.iter().map(|r| r.0).collect();
            let dones: Vec<bool> = results.iter().map(|r| r.1).collect();
            rollout.push(&obs_snapshot, &actions, &rewards, &dones, values.data(), &logps);
            step += n_envs;
        }

        // Bootstrap values for the final observation.
        act_in[i_act_obs] = Tensor::new(vec![n_envs, obs_dim], venv.obs().to_vec())?;
        let out = act_prog.run(&act_in)?;
        let batch_data = rollout.finish(out[1].data(), gamma, lam);

        let epochs = ppo_epochs.max(1);
        for _ in 0..epochs {
            adam_t += 1.0;
            train_in[i_batch0] = batch_data.obs.clone();
            train_in[i_batch0 + 1] = batch_data.actions.clone();
            train_in[i_batch0 + 2] = batch_data.returns.clone();
            train_in[i_batch0 + 3] = batch_data.advantages.clone();
            if has_old_logp {
                train_in[i_batch0 + 4] = batch_data.old_logp.clone();
            }
            train_in[i_hyper] = Tensor::vec1(&make_hyper(step, adam_t));
            let t0 = std::time::Instant::now();
            let out = train_prog.run(&train_in)?;
            log.train_exec_secs += t0.elapsed().as_secs_f64();
            for i in 0..n_all {
                train_in[i] = out[i].clone();
                train_in[n_all + i] = out[n_all + i].clone();
                train_in[2 * n_all + i] = out[2 * n_all + i].clone();
            }
            train_in[i_qstate] = out[3 * n_all].clone();
            if log_every > 0 && step % log_every < n_envs * n_steps {
                let pg = out[3 * n_all + 1].data()[0];
                log.losses.push((step, pg));
            }
        }

        for stat in venv.take_finished() {
            log.episodes += 1;
            log.returns.push((step, stat.ret));
        }

        // Fig-1 style probe: hand current params + qstate to the caller
        // on a step cadence (e.g. action-distribution variance eval).
        if probe_every > 0 && step % probe_every < n_envs * n_steps {
            probe(step, &train_in[..n_all], &train_in[i_qstate]);
        }
    }

    // Final return: mean of the last 20 episodes.
    let tail: Vec<f32> = log
        .returns
        .iter()
        .rev()
        .take(20)
        .map(|&(_, r)| r)
        .collect();
    log.final_return = if tail.is_empty() {
        0.0
    } else {
        tail.iter().sum::<f32>() / tail.len() as f32
    };
    log.wall_secs = t_start.elapsed().as_secs_f64();
    // Down-sample the per-episode log to (step, smoothed) pairs.
    if log_every > 0 {
        let mut sm = Vec::new();
        let mut avg = None::<f32>;
        for &(s, r) in &log.returns {
            avg = Some(match avg {
                None => r,
                Some(a) => 0.95 * a + 0.05 * r,
            });
            sm.push((s, avg.unwrap()));
        }
        log.returns = sm;
    }

    for i in 0..n_all {
        params.tensors[i] = train_in[i].clone();
    }
    Ok((
        TrainedPolicy {
            algo: algo.into(),
            env_id: env_id.into(),
            arch,
            params,
            qstate: train_in[i_qstate].clone(),
            quant,
            steps: total_steps,
        },
        log,
    ))
}

/// Train an A2C policy.
pub fn train(rt: &Runtime, cfg: &A2cConfig) -> Result<(TrainedPolicy, TrainLog)> {
    train_probed(rt, cfg, 0, &mut |_, _, _| {})
}

/// Train with a periodic parameter probe (Fig-1 variance tracking).
pub fn train_probed(
    rt: &Runtime,
    cfg: &A2cConfig,
    probe_every: usize,
    probe: &mut dyn FnMut(usize, &[Tensor], &Tensor),
) -> Result<(TrainedPolicy, TrainLog)> {
    let (lr, bits, delay) = (cfg.lr, cfg.quant.bits as f32, cfg.quant.delay as f32);
    let (vf, ent) = (cfg.vf_coef, cfg.ent_coef);
    train_onpolicy(
        rt,
        "a2c",
        &cfg.env_id,
        cfg.arch_key.clone(),
        cfg.layer_norm,
        cfg.total_steps,
        cfg.n_envs,
        cfg.n_steps,
        cfg.gamma,
        cfg.gae_lambda,
        cfg.quant,
        cfg.seed,
        cfg.log_every,
        move |step, t| vec![lr, bits, step as f32, delay, t, vf, ent],
        1,
        probe_every,
        probe,
    )
}

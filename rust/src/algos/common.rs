//! Shared trainer plumbing: quantization schedules, trained-policy
//! artifacts, and helpers for assembling program inputs.

use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::{ParamSet, Program, Runtime};
use crate::tensor::Tensor;

/// QAT schedule — mirrors the paper's (bits, quant_delay) controls.
/// `bits = 0` disables quantization entirely (fp32 training); the same
/// AOT program serves every setting because bits/step/delay are runtime
/// tensor inputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantSchedule {
    pub bits: u32,
    pub delay: usize,
}

impl QuantSchedule {
    pub fn off() -> Self {
        QuantSchedule { bits: 0, delay: 0 }
    }

    pub fn qat(bits: u32, delay: usize) -> Self {
        QuantSchedule { bits, delay }
    }

    pub fn is_on(&self) -> bool {
        self.bits > 0
    }
}

/// A trained policy: everything evaluation and PTQ need.
#[derive(Debug, Clone)]
pub struct TrainedPolicy {
    pub algo: String,
    pub env_id: String,
    /// Architecture name (prefix of the act/train program names).
    pub arch: String,
    /// Full parameter set in act-program input order (policy+value for
    /// a2c/ppo, q-net for dqn, actor for ddpg).
    pub params: ParamSet,
    /// QAT range state captured during training ((T, 2) min/max rows).
    pub qstate: Tensor,
    /// Training-time quantization schedule (for QAT-mode evaluation).
    pub quant: QuantSchedule,
    /// Steps actually trained.
    pub steps: usize,
}

impl TrainedPolicy {
    /// Persist to `<dir>/<algo>_<env>[_qN].qprm` (+ qstate rows appended).
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<std::path::PathBuf> {
        let name = self.file_name();
        let path = dir.as_ref().join(name);
        let mut with_state = self.params.clone();
        with_state.names.push("__qstate".into());
        with_state.tensors.push(self.qstate.clone());
        with_state.names.push("__meta".into());
        with_state.tensors.push(Tensor::vec1(&[
            self.quant.bits as f32,
            self.quant.delay as f32,
            self.steps as f32,
        ]));
        with_state.save(&path)?;
        Ok(path)
    }

    pub fn file_name(&self) -> String {
        if self.quant.is_on() {
            format!("{}_{}_q{}.qprm", self.algo, self.env_id, self.quant.bits)
        } else {
            format!("{}_{}.qprm", self.algo, self.env_id)
        }
    }

    /// Load a policy saved by [`TrainedPolicy::save`].
    pub fn load(path: impl AsRef<Path>, algo: &str, env_id: &str, arch: &str) -> Result<TrainedPolicy> {
        let mut set = ParamSet::load(&path)?;
        let meta = set
            .tensors
            .pop()
            .ok_or_else(|| Error::Manifest("policy file missing meta".into()))?;
        set.names.pop();
        let qstate = set
            .tensors
            .pop()
            .ok_or_else(|| Error::Manifest("policy file missing qstate".into()))?;
        set.names.pop();
        let m = meta.data();
        Ok(TrainedPolicy {
            algo: algo.into(),
            env_id: env_id.into(),
            arch: arch.into(),
            params: set,
            qstate,
            quant: QuantSchedule { bits: m[0] as u32, delay: m[1] as usize },
            steps: m[2] as usize,
        })
    }
}

/// Resolve the arch name for an (algo, env[, variant]) key and load its
/// act+train programs.
pub fn load_programs(
    rt: &Runtime,
    key: &str,
) -> Result<(String, std::rc::Rc<Program>, std::rc::Rc<Program>)> {
    let arch = rt.manifest.arch_for(key)?.to_string();
    let act = rt.load(&format!("{arch}_act"))?;
    let train = rt.load(&format!("{arch}_train"))?;
    Ok((arch, act, train))
}

/// Pad a single observation into an (act_batch, obs_dim) tensor.
pub fn pad_obs(obs: &[f32], batch: usize) -> Tensor {
    let mut data = Vec::with_capacity(batch * obs.len());
    for _ in 0..batch {
        data.extend_from_slice(obs);
    }
    Tensor::new(vec![batch, obs.len()], data).unwrap()
}

/// Exploration epsilon schedule (paper Table 9: final eps with a linear
/// fraction of training).
#[derive(Debug, Clone, Copy)]
pub struct EpsSchedule {
    pub start: f32,
    pub end: f32,
    /// Fraction of total steps over which epsilon anneals.
    pub fraction: f32,
}

impl EpsSchedule {
    pub fn value(&self, step: usize, total: usize) -> f32 {
        let horizon = (total as f32 * self.fraction).max(1.0);
        let t = (step as f32 / horizon).min(1.0);
        self.start + t * (self.end - self.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eps_schedule_anneals_linearly() {
        let e = EpsSchedule { start: 1.0, end: 0.01, fraction: 0.1 };
        assert_eq!(e.value(0, 1000), 1.0);
        let mid = e.value(50, 1000);
        assert!((mid - 0.505).abs() < 1e-3, "{mid}");
        assert!((e.value(100, 1000) - 0.01).abs() < 1e-6);
        assert!((e.value(900, 1000) - 0.01).abs() < 1e-6, "clamped after the fraction");
    }

    #[test]
    fn pad_obs_repeats_rows() {
        let t = pad_obs(&[1.0, 2.0], 3);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.row(2), &[1.0, 2.0]);
    }

    #[test]
    fn policy_round_trip() {
        let p = TrainedPolicy {
            algo: "dqn".into(),
            env_id: "cartpole".into(),
            arch: "dqn_o4a2h64x64".into(),
            params: ParamSet {
                names: vec!["q.w0".into()],
                tensors: vec![Tensor::vec1(&[1.0, 2.0])],
            },
            qstate: Tensor::new(vec![2, 2], vec![0.0, 1.0, -1.0, 2.0]).unwrap(),
            quant: QuantSchedule::qat(8, 500),
            steps: 1234,
        };
        let dir = std::env::temp_dir().join("quarl_policy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = p.save(&dir).unwrap();
        let q = TrainedPolicy::load(&path, "dqn", "cartpole", "dqn_o4a2h64x64").unwrap();
        assert_eq!(q.params.tensors[0].data(), &[1.0, 2.0]);
        assert_eq!(q.qstate, p.qstate);
        assert_eq!(q.quant, p.quant);
        assert_eq!(q.steps, 1234);
    }
}

//! DQN trainer (Mnih et al. 2013) driving the AOT train/act programs.
//!
//! The Rust side owns: environment stepping, epsilon-greedy exploration,
//! prioritized replay, the target-network copy schedule, and the QAT
//! step/delay bookkeeping. The XLA side owns the entire numeric train
//! step (forward, TD loss, Adam, fake-quant range tracking).
//!
//! Hyperparameter defaults follow paper Table 9, with step budgets
//! scaled to the proxy environments (DESIGN.md §2).

use std::cell::RefCell;

use crate::actorq::learner::HarnessConfig;
use crate::actorq::{ActorQConfig, ActorQLog, Exploration, LearnerHarness, ReturnLog};
use crate::algos::common::{load_programs, pad_obs, EpsSchedule, QuantSchedule, TrainedPolicy};
use crate::envs::api::Action;
use crate::envs::registry::make_env;
use crate::error::Result;
use crate::replay::{PrioritizedReplay, Transition};
use crate::rng::Pcg32;
use crate::runtime::{ParamSet, Runtime};
use crate::sustain::Component;
use crate::tensor::Tensor;

/// DQN configuration (paper Table 9 shape, scaled budgets).
#[derive(Debug, Clone)]
pub struct DqnConfig {
    pub env_id: String,
    /// env_arch_map key override (e.g. "dqn/pong_lite/mp_a"); default
    /// is `dqn/<env_id>`.
    pub arch_key: Option<String>,
    pub total_steps: usize,
    pub buffer_size: usize,
    pub warmup: usize,
    pub train_freq: usize,
    pub target_update: usize,
    pub lr: f32,
    pub gamma: f32,
    pub eps: EpsSchedule,
    pub per_alpha: f32,
    pub per_beta: f32,
    pub quant: QuantSchedule,
    pub seed: u64,
    /// Progress callback cadence (steps); 0 = silent.
    pub log_every: usize,
}

impl DqnConfig {
    pub fn new(env_id: &str) -> Self {
        DqnConfig {
            env_id: env_id.into(),
            arch_key: None,
            total_steps: 40_000,
            buffer_size: 10_000,
            warmup: 1_000,
            train_freq: 1,
            target_update: 250,
            lr: 2.5e-4,
            gamma: 0.99,
            eps: EpsSchedule { start: 1.0, end: 0.01, fraction: 0.1 },
            per_alpha: 0.6,
            per_beta: 0.4,
            quant: QuantSchedule::off(),
            seed: 0,
            log_every: 0,
        }
    }
}

/// Per-run training telemetry.
#[derive(Debug, Default, Clone)]
pub struct TrainLog {
    /// (step, mean recent return) samples.
    pub returns: Vec<(usize, f32)>,
    /// (step, loss) samples.
    pub losses: Vec<(usize, f32)>,
    pub episodes: usize,
    pub final_return: f32,
    /// Wall-clock seconds inside the train-program calls only.
    pub train_exec_secs: f64,
    /// Total wall-clock seconds.
    pub wall_secs: f64,
}

/// Train a DQN policy through the full Rust -> PJRT stack.
pub fn train(rt: &Runtime, cfg: &DqnConfig) -> Result<(TrainedPolicy, TrainLog)> {
    let key = cfg.arch_key.clone().unwrap_or_else(|| format!("dqn/{}", cfg.env_id));
    let (arch, act_prog, train_prog) = load_programs(rt, &key)?;
    let spec = &train_prog.spec;
    let n_p = spec.count("n_params")?;
    let n_q = spec.n_qstate;
    let batch = spec.arch.train_batch;
    let act_batch = act_prog.spec.arch.act_batch;
    let n_actions = spec.arch.act_dim;

    let mut root = Pcg32::new(cfg.seed, 17);
    let mut env_rng = root.split(1);
    let mut explore_rng = root.split(2);
    let mut replay_rng = root.split(3);
    let mut init_rng = root.split(4);

    let mut env = make_env(&cfg.env_id)?;
    let obs_dim = env.obs_dim();
    let mut params = ParamSet::init(&spec.inputs[..n_p], &mut init_rng);
    let zeros = params.zeros_like();

    // Persistent train-program input slots (avoid rebuilding per call).
    // Layout: params, target, m, v, qstate, obs, act, rew, nobs, done, isw, hyper
    let mut train_in: Vec<Tensor> = Vec::new();
    train_in.extend(params.tensors.iter().cloned());
    train_in.extend(params.tensors.iter().cloned()); // target
    train_in.extend(zeros.tensors.iter().cloned()); // m
    train_in.extend(zeros.tensors.iter().cloned()); // v
    train_in.push(Tensor::zeros(vec![n_q, 2]));
    train_in.push(Tensor::zeros(vec![batch, obs_dim]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::zeros(vec![batch, obs_dim]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::vec1(&[cfg.lr, cfg.gamma, 0.0, 0.0, 0.0, 1.0]));
    let i_qstate = 4 * n_p;
    let i_obs = i_qstate + 1;
    let i_hyper = i_obs + 6;

    let mut per = PrioritizedReplay::new(cfg.buffer_size, obs_dim, 1, cfg.per_alpha);
    let mut obs = vec![0.0f32; obs_dim];
    let mut next_obs = vec![0.0f32; obs_dim];
    env.reset(&mut env_rng, &mut obs);

    let mut log = TrainLog::default();
    let t_start = std::time::Instant::now();
    let mut ep_return = 0.0f32;
    let mut recent: Vec<f32> = Vec::new();
    let mut adam_t = 0.0f32;

    let quant_bits = cfg.quant.bits as f32;
    let quant_delay = cfg.quant.delay as f32;

    for step in 0..cfg.total_steps {
        // --- act ---
        let eps = cfg.eps.value(step, cfg.total_steps);
        let a = if explore_rng.uniform() < eps {
            explore_rng.below_usize(n_actions)
        } else {
            let mut act_in: Vec<Tensor> = train_in[..n_p].to_vec();
            act_in.push(train_in[i_qstate].clone());
            act_in.push(pad_obs(&obs, act_batch));
            act_in.push(Tensor::vec1(&[quant_bits, step as f32, quant_delay]));
            let out = act_prog.run(&act_in)?;
            // Shared NaN-safe argmax: same selection rule as the ActorQ
            // actors, the evaluator, and the deployment experiments.
            crate::tensor::argmax(out[0].row(0))
        };

        // --- env step ---
        let s = env.step(&Action::Discrete(a), &mut env_rng, &mut next_obs);
        ep_return += s.reward;
        per.push(Transition {
            obs: &obs,
            action: &[a as f32],
            reward: s.reward,
            next_obs: &next_obs,
            done: s.done,
        });
        if s.done {
            log.episodes += 1;
            recent.push(ep_return);
            ep_return = 0.0;
            env.reset(&mut env_rng, &mut obs);
        } else {
            obs.copy_from_slice(&next_obs);
        }

        // --- learn ---
        if step >= cfg.warmup && step % cfg.train_freq == 0 && per.len() >= batch {
            let beta = cfg.per_beta + (1.0 - cfg.per_beta) * (step as f32 / cfg.total_steps as f32);
            let b = per.sample(batch, beta, &mut replay_rng);
            adam_t += 1.0;
            train_in[i_obs] = b.obs;
            train_in[i_obs + 1] = b.actions;
            train_in[i_obs + 2] = b.rewards;
            train_in[i_obs + 3] = b.next_obs;
            train_in[i_obs + 4] = b.dones;
            train_in[i_obs + 5] = b.weights;
            train_in[i_hyper] = Tensor::vec1(&[
                cfg.lr, cfg.gamma, quant_bits, step as f32, quant_delay, adam_t,
            ]);
            let t0 = std::time::Instant::now();
            let out = train_prog.run(&train_in)?;
            log.train_exec_secs += t0.elapsed().as_secs_f64();
            // write back: params, m, v, qstate
            for i in 0..n_p {
                train_in[i] = out[i].clone();
                train_in[2 * n_p + i] = out[n_p + i].clone();
                train_in[3 * n_p + i] = out[2 * n_p + i].clone();
            }
            train_in[i_qstate] = out[3 * n_p].clone();
            let loss = out[3 * n_p + 1].data()[0];
            let td = &out[3 * n_p + 2];
            per.update_priorities(&b.indices, td.data());
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                log.losses.push((step, loss));
            }
        }

        if step >= cfg.warmup && step % cfg.target_update == 0 {
            for i in 0..n_p {
                train_in[n_p + i] = train_in[i].clone();
            }
        }

        if cfg.log_every > 0 && step % cfg.log_every == 0 && !recent.is_empty() {
            let tail = &recent[recent.len().saturating_sub(20)..];
            let mean = tail.iter().sum::<f32>() / tail.len() as f32;
            log.returns.push((step, mean));
        }
    }

    let tail = &recent[recent.len().saturating_sub(20)..];
    log.final_return = if tail.is_empty() {
        ep_return
    } else {
        tail.iter().sum::<f32>() / tail.len() as f32
    };
    log.wall_secs = t_start.elapsed().as_secs_f64();

    for i in 0..n_p {
        params.tensors[i] = train_in[i].clone();
    }
    Ok((
        TrainedPolicy {
            algo: "dqn".into(),
            env_id: cfg.env_id.clone(),
            arch,
            params,
            qstate: train_in[i_qstate].clone(),
            quant: cfg.quant,
            steps: cfg.total_steps,
        },
        log,
    ))
}

/// Train a DQN policy with the ActorQ actor-learner driver (paper §3).
///
/// N actor threads collect experience on quantized policy copies (the
/// pure-Rust deployment engines at any engine-supported
/// [`crate::quant::Precision`] — int8, packed int4, fp32 baseline; no
/// PJRT on the actor side; each vec-env sweep is one batched
/// `forward_batch`, so weight panels stream once per sweep rather than
/// once per env) while this thread drains the experience channel into
/// prioritized replay, runs the train program, and
/// quantizes-on-broadcast fresh parameters every `acfg.broadcast_every`
/// updates. Pool setup, the drain + pacer loop, and the log assembly
/// live in the shared [`LearnerHarness`]; this driver contributes the
/// DQN train-program closure. The train-step : env-step ratio and all
/// schedules match [`train`] at equal step budget, so the two drivers
/// converge to the same reward floor (pinned by
/// `rust/tests/actorq_smoke.rs`).
pub fn train_actorq(
    rt: &Runtime,
    cfg: &DqnConfig,
    acfg: &ActorQConfig,
) -> Result<(TrainedPolicy, ActorQLog)> {
    let key = cfg.arch_key.clone().unwrap_or_else(|| format!("dqn/{}", cfg.env_id));
    let (arch, _act_prog, train_prog) = load_programs(rt, &key)?;
    let spec = &train_prog.spec;
    let n_p = spec.count("n_params")?;
    let n_q = spec.n_qstate;
    let batch = spec.arch.train_batch;

    let mut root = Pcg32::new(cfg.seed, 53);
    let mut replay_rng = root.split(1);
    let mut init_rng = root.split(2);

    let probe = make_env(&cfg.env_id)?;
    let obs_dim = probe.obs_dim();
    drop(probe);

    let mut params = ParamSet::init(&spec.inputs[..n_p], &mut init_rng);
    let zeros = params.zeros_like();

    // Same train-program slot layout as the synchronous driver:
    // params, target, m, v, qstate, obs, act, rew, nobs, done, isw, hyper
    let mut train_in: Vec<Tensor> = Vec::new();
    train_in.extend(params.tensors.iter().cloned());
    train_in.extend(params.tensors.iter().cloned()); // target
    train_in.extend(zeros.tensors.iter().cloned()); // m
    train_in.extend(zeros.tensors.iter().cloned()); // v
    train_in.push(Tensor::zeros(vec![n_q, 2]));
    train_in.push(Tensor::zeros(vec![batch, obs_dim]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::zeros(vec![batch, obs_dim]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::zeros(vec![batch]));
    train_in.push(Tensor::vec1(&[cfg.lr, cfg.gamma, 0.0, 0.0, 0.0, 1.0]));
    let i_qstate = 4 * n_p;
    let i_obs = i_qstate + 1;
    let i_hyper = i_obs + 6;

    // Each actor anneals epsilon over its share of the step budget, which
    // reproduces the global schedule without cross-thread coordination.
    // The harness owns pool setup, the drain + pacer loop, and the log
    // assembly; acfg.precision enters the stack exactly once, here.
    let horizon = (cfg.total_steps / acfg.n_actors.max(1)).max(1);
    let harness = LearnerHarness::spawn(
        &params,
        &HarnessConfig {
            env_id: &cfg.env_id,
            seed: cfg.seed,
            total_steps: cfg.total_steps,
            warmup: cfg.warmup,
            train_freq: cfg.train_freq,
            log_every: cfg.log_every,
            exploration: Exploration::EpsGreedy { schedule: cfg.eps, horizon },
            returns: ReturnLog::TailMean,
            acfg,
            faults: None,
            ckpt: None,
            resume: None,
        },
    )?;
    let meter = harness.meter.clone();
    let broadcast = harness.broadcast.clone();

    // Both the push hook and the train closure touch the replay buffer;
    // the harness never runs them concurrently, so a RefCell suffices.
    let per = RefCell::new(PrioritizedReplay::new(cfg.buffer_size, obs_dim, 1, cfg.per_alpha));
    let mut adam_t = 0.0f32;
    let mut trains = 0usize;
    let mut exec_secs = 0.0f64;
    let target_every = (cfg.target_update / cfg.train_freq.max(1)).max(1);

    let quant_bits = cfg.quant.bits as f32;
    let quant_delay = cfg.quant.delay as f32;

    let mut log = harness.run(
        |t| {
            per.borrow_mut().push(Transition {
                obs: &t.obs,
                action: &t.action,
                reward: t.reward,
                next_obs: &t.next_obs,
                done: t.done,
            });
        },
        |step, publish| {
            let mut per = per.borrow_mut();
            if per.len() < batch {
                return Ok(None);
            }
            let beta =
                cfg.per_beta + (1.0 - cfg.per_beta) * (step as f32 / cfg.total_steps as f32);
            let b = per.sample(batch, beta, &mut replay_rng);
            adam_t += 1.0;
            train_in[i_obs] = b.obs;
            train_in[i_obs + 1] = b.actions;
            train_in[i_obs + 2] = b.rewards;
            train_in[i_obs + 3] = b.next_obs;
            train_in[i_obs + 4] = b.dones;
            train_in[i_obs + 5] = b.weights;
            train_in[i_hyper] = Tensor::vec1(&[
                cfg.lr, cfg.gamma, quant_bits, step as f32, quant_delay, adam_t,
            ]);
            let t0 = std::time::Instant::now();
            let out = {
                let _busy = meter.scope(Component::Learner);
                train_prog.run(&train_in)?
            };
            exec_secs += t0.elapsed().as_secs_f64();
            meter.add_steps(Component::Learner, 1);
            for i in 0..n_p {
                train_in[i] = out[i].clone();
                train_in[2 * n_p + i] = out[n_p + i].clone();
                train_in[3 * n_p + i] = out[2 * n_p + i].clone();
            }
            train_in[i_qstate] = out[3 * n_p].clone();
            per.update_priorities(&b.indices, out[3 * n_p + 2].data());
            trains += 1;

            if trains % target_every == 0 {
                for i in 0..n_p {
                    train_in[n_p + i] = train_in[i].clone();
                }
            }
            if publish {
                for i in 0..n_p {
                    params.tensors[i] = train_in[i].clone();
                }
                {
                    let _busy = meter.scope(Component::Broadcast);
                    broadcast.publish(&params)?;
                }
                meter.add_steps(Component::Broadcast, 1);
            }
            Ok(Some(out[3 * n_p + 1].data()[0]))
        },
    )?;
    log.train_exec_secs = exec_secs;

    for i in 0..n_p {
        params.tensors[i] = train_in[i].clone();
    }
    Ok((
        TrainedPolicy {
            algo: "dqn".into(),
            env_id: cfg.env_id.clone(),
            arch,
            params,
            qstate: train_in[i_qstate].clone(),
            quant: cfg.quant,
            steps: log.env_steps,
        },
        log,
    ))
}

//! PPO trainer (Schulman et al. 2017): clipped-surrogate on-policy
//! optimization sharing the A2C rollout machinery (one whole-batch act
//! call per vec-env sweep, allocation-free per-row selection).

use crate::algos::a2c::{train_onpolicy, TrainLog};
use crate::algos::common::{QuantSchedule, TrainedPolicy};
use crate::error::Result;
use crate::runtime::Runtime;

#[derive(Debug, Clone)]
pub struct PpoConfig {
    pub env_id: String,
    pub arch_key: Option<String>,
    pub total_steps: usize,
    pub n_envs: usize,
    pub n_steps: usize,
    pub lr: f32,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub vf_coef: f32,
    pub ent_coef: f32,
    pub clip: f32,
    /// Gradient epochs per rollout (PPO2's n_epochs).
    pub epochs: usize,
    pub quant: QuantSchedule,
    pub seed: u64,
    pub log_every: usize,
    pub layer_norm: bool,
}

impl PpoConfig {
    pub fn new(env_id: &str) -> Self {
        PpoConfig {
            env_id: env_id.into(),
            arch_key: None,
            total_steps: 150_000,
            n_envs: 8,
            n_steps: 16,
            lr: 3e-4,
            gamma: 0.99,
            gae_lambda: 0.95,
            vf_coef: 0.5,
            ent_coef: 0.01,
            clip: 0.2,
            epochs: 4,
            quant: QuantSchedule::off(),
            seed: 0,
            log_every: 0,
            layer_norm: false,
        }
    }
}

/// Train a PPO policy.
pub fn train(rt: &Runtime, cfg: &PpoConfig) -> Result<(TrainedPolicy, TrainLog)> {
    train_probed(rt, cfg, 0, &mut |_, _, _| {})
}

/// Train with a periodic parameter probe (Fig-1 variance tracking).
pub fn train_probed(
    rt: &Runtime,
    cfg: &PpoConfig,
    probe_every: usize,
    probe: &mut dyn FnMut(usize, &[crate::tensor::Tensor], &crate::tensor::Tensor),
) -> Result<(TrainedPolicy, TrainLog)> {
    let (lr, bits, delay) = (cfg.lr, cfg.quant.bits as f32, cfg.quant.delay as f32);
    let (vf, ent, clip) = (cfg.vf_coef, cfg.ent_coef, cfg.clip);
    train_onpolicy(
        rt,
        "ppo",
        &cfg.env_id,
        cfg.arch_key.clone(),
        cfg.layer_norm,
        cfg.total_steps,
        cfg.n_envs,
        cfg.n_steps,
        cfg.gamma,
        cfg.gae_lambda,
        cfg.quant,
        cfg.seed,
        cfg.log_every,
        move |step, t| vec![lr, bits, step as f32, delay, t, vf, ent, clip],
        cfg.epochs,
        probe_every,
        probe,
    )
}

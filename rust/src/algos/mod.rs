//! RL trainers driving the AOT-compiled XLA programs.
//!
//! Each trainer owns the non-differentiable side of its algorithm
//! (environments, exploration, replay, schedules); the numeric train
//! step lives in the AOT programs (python/compile/algos/*), one compiled
//! executable per architecture.

pub mod a2c;
pub mod common;
pub mod ddpg;
pub mod dqn;
pub mod ppo;

pub use common::{EpsSchedule, QuantSchedule, TrainedPolicy};
pub use dqn::TrainLog;

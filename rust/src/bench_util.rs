//! Tiny benchmark runner for the `harness = false` benches (no criterion
//! offline). Reports min/median/mean over timed batches after a warmup,
//! which is what the EXPERIMENTS.md §Perf tables quote.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters_per_batch: usize,
    pub batches: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl BenchStats {
    pub fn per_iter_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
}

/// Time `f` (called `iters` times per batch, `batches` batches after one
/// warmup batch) and print a row.
pub fn bench(name: &str, iters: usize, batches: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..iters {
        f(); // warmup
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(batches);
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        iters_per_batch: iters,
        batches,
        min_ns: per_iter[0],
        median_ns: per_iter[batches / 2],
        mean_ns: per_iter.iter().sum::<f64>() / batches as f64,
    };
    println!(
        "{:<44} {:>12.3} us/iter (min {:.3}, mean {:.3})",
        stats.name,
        stats.median_ns / 1e3,
        stats.min_ns / 1e3,
        stats.mean_ns / 1e3
    );
    stats
}

/// Black-box: defeat dead-code elimination on a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

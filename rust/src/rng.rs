//! Deterministic, seedable PRNG for environments, exploration, and
//! experiment reproducibility.
//!
//! The offline crate set has no `rand`, so we carry our own PCG-XSH-RR
//! 64/32 implementation (O'Neill 2014). Every environment, replay buffer,
//! and trainer owns its own stream, split from the experiment seed, so
//! runs are bit-reproducible regardless of thread scheduling.

/// SplitMix64 finalizer (Steele et al. 2014): a full-avalanche bijection
/// on `u64` — flipping any input bit flips each output bit with
/// probability ~1/2. Use it whenever a "nearby" integer (thread id,
/// shard index, seed+1 sweep) must become a statistically unrelated
/// seed; a plain XOR or add visibly correlates adjacent streams.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive a decorrelated child seed from `(seed, stream)`. The golden
/// ratio spreads the stream index across the word before the avalanche,
/// so `(seed, id)` and `(seed + 1, id - 1)`-style near-collisions — which
/// the old `seed ^ (const + id)` derivation mapped to the *same* value —
/// land in unrelated places.
#[inline]
pub fn mix_seed(seed: u64, stream: u64) -> u64 {
    splitmix64(seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(stream.wrapping_add(1))))
}

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Distinct stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Expose the raw `(state, inc)` pair so checkpoints can persist the
    /// generator mid-stream. Restoring via [`Pcg32::from_state`] resumes
    /// the exact sequence — the foundation of bit-identical resume.
    #[inline]
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a `(state, inc)` pair previously captured
    /// with [`Pcg32::state_parts`]. No seeding or warm-up runs: the next
    /// draw continues where the captured generator left off.
    #[inline]
    pub fn from_state(state: u64, inc: u64) -> Pcg32 {
        Pcg32 { state, inc }
    }

    /// Derive a child generator; used to split one experiment seed into
    /// per-component streams (env i, replay, exploration, ...).
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg32::new(seed, stream)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next u64 (two draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 random mantissa bits => exactly representable, unbiased.
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection
    /// method — unbiased for all n.
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut lo = m as u32;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        assert!(n > 0 && n <= u32::MAX as usize, "below_usize out of range: {n}");
        self.below(n as u32) as usize
    }

    /// Standard normal via Box–Muller (cached second value discarded for
    /// simplicity; exploration noise is not on the hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f32::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        assert!(total > 0.0, "categorical with non-positive total weight");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be independent, {same} collisions");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::new(7, 0);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Pcg32::new(3, 9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::new(11, 4);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(5, 5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg32::new(13, 1);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(17, 2);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn mix_seed_grid_is_distinct_and_avalanched() {
        // Adjacent (seed, id) pairs — exactly what an actor pool derives
        // env seeds from — must land far apart.
        let mut seen = std::collections::HashSet::new();
        for seed in 0..5u64 {
            for id in 0..5u64 {
                assert!(seen.insert(mix_seed(seed, id)), "collision at ({seed}, {id})");
            }
        }
        // Avalanche: neighboring ids differ in roughly half the 64 bits.
        for seed in 0..8u64 {
            for id in 0..8u64 {
                let d = (mix_seed(seed, id) ^ mix_seed(seed, id + 1)).count_ones();
                assert!((10..=54).contains(&d), "weak diffusion: {d} bits at ({seed}, {id})");
            }
        }
    }

    #[test]
    fn mix_seed_decorrelates_pcg_streams() {
        // Streams seeded from adjacent grid points behave independently.
        for seed in 0..3u64 {
            for id in 0..3u64 {
                let mut a = Pcg32::new(mix_seed(seed, id), 0);
                let mut b = Pcg32::new(mix_seed(seed, id + 1), 0);
                let mut c = Pcg32::new(mix_seed(seed + 1, id), 0);
                let ab = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
                let ac = (0..64).filter(|_| a.next_u32() == c.next_u32()).count();
                assert!(ab < 4 && ac < 4, "correlated streams at ({seed}, {id}): {ab}/{ac}");
            }
        }
    }

    #[test]
    fn mix_seed_fixes_the_xor_derivation_collision() {
        // Regression: the old `seed ^ (0x9e37 + id)` scheme mapped
        // (s, 0) and (s ^ 0xf, 1) to the SAME env seed, because
        // 0x9e37 ^ 0x9e38 == 0xf — two different runs shared identical
        // env streams. The mixed derivation must keep them apart.
        let s = 12345u64;
        let old = |seed: u64, id: u64| seed ^ (0x9e37 + id);
        assert_eq!(old(s, 0), old(s ^ 0xf, 1), "premise: old scheme collides");
        assert_ne!(mix_seed(s, 0), mix_seed(s ^ 0xf, 1));
    }

    #[test]
    fn state_roundtrip_resumes_the_exact_sequence() {
        let mut a = Pcg32::new(99, 7);
        for _ in 0..37 {
            a.next_u32(); // advance mid-stream
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_state(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn split_children_independent() {
        let mut root = Pcg32::new(1, 0);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}

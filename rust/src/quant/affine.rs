//! Uniform affine quantization — the paper's §3.1 scheme, bit-exact with
//! the Python oracle (`python/compile/kernels/ref.py`):
//!
//! ```text
//! delta = (|min(W,0)| + |max(W,0)|) / 2^n
//! z     = floor(-min(W,0) / delta)
//! Q(W)  = clip(floor(W/delta) + z, 0, 2^n - 1)
//! D(q)  = delta * (q - z)
//! ```
//!
//! Zero is always exactly representable (ranges are expanded to include
//! 0), matching TFLite's asymmetric quantizer the paper uses. The golden
//! tests in `rust/tests/quant_golden.rs` pin this against vectors
//! generated from the jnp reference.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Quantization parameters for one tensor (or one axis slice).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub delta: f32,
    pub zero_point: f32,
    pub levels: f32,
}

impl QParams {
    /// Derive parameters from an observed range for `bits`-bit quantization.
    pub fn from_range(vmin: f32, vmax: f32, bits: u32) -> Result<QParams> {
        if bits == 0 || bits > 31 {
            return Err(Error::Quant(format!("bitwidth {bits} out of range [1, 31]")));
        }
        let vmin = vmin.min(0.0);
        let vmax = vmax.max(0.0);
        let levels = (1u64 << bits) as f32;
        let mut delta = (vmin.abs() + vmax.abs()) / levels;
        if delta <= 0.0 {
            delta = 1.0; // degenerate all-zero range; everything maps to z
        }
        let zero_point = (-vmin / delta).floor();
        Ok(QParams { delta, zero_point, levels })
    }

    /// Quantize one value to the integer grid (pre-clip integer code).
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        let q = (x / self.delta).floor() + self.zero_point;
        q.max(0.0).min(self.levels - 1.0)
    }

    /// Dequantize an integer code.
    #[inline]
    pub fn dequantize(&self, q: f32) -> f32 {
        self.delta * (q - self.zero_point)
    }

    /// Quantize-dequantize (the "fake quant" used for reward evaluation).
    #[inline]
    pub fn roundtrip(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    /// Quantize to a zero-point-centered code on a `bits`-wide signed
    /// grid — the deployment rule every quantized engine bitwidth and
    /// the ActorQ broadcast path share.
    ///
    /// The [0, levels-1] clip lives in [`QParams::quantize`]; the signed
    /// saturation (codes past ±2^(bits-1) pin to the rail, which happens
    /// for strongly asymmetric ranges where the zero point sits far from
    /// the middle of the grid) lives here, so every integer consumer —
    /// i8 storage or packed nibbles — clamps the same way. `bits` must
    /// be in 2..=8 so the code fits an i8.
    #[inline]
    pub fn quantize_code(&self, x: f32, bits: u32) -> i8 {
        debug_assert!((2..=8).contains(&bits), "centered codes need bits in 2..=8");
        let hi = ((1i32 << (bits - 1)) - 1) as f32;
        let lo = -hi - 1.0;
        (self.quantize(x) - self.zero_point).max(lo).min(hi) as i8
    }

    /// Quantize to a zero-point-centered i8 code — the 8-bit special
    /// case of [`QParams::quantize_code`], kept because it is the grid
    /// the int8 engine and its golden tests pin.
    #[inline]
    pub fn quantize_i8(&self, x: f32) -> i8 {
        self.quantize_code(x, 8)
    }

    /// Dequantize a centered code produced by [`QParams::quantize_code`]
    /// (any bitwidth — the grid step alone sets the scale).
    #[inline]
    pub fn dequantize_i8(&self, code: i8) -> f32 {
        self.delta * code as f32
    }
}

/// Per-tensor fake quantization in place.
pub fn fake_quant_slice(xs: &mut [f32], bits: u32) -> Result<QParams> {
    if xs.is_empty() {
        return Err(Error::Quant("fake_quant of empty slice".into()));
    }
    let vmin = xs.iter().copied().fold(f32::INFINITY, f32::min);
    let vmax = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let qp = QParams::from_range(vmin, vmax, bits)?;
    for x in xs.iter_mut() {
        *x = qp.roundtrip(*x);
    }
    Ok(qp)
}

/// Per-tensor fake quantization with a fixed (externally monitored) range
/// — the QAT-eval path (paper Algorithm 2 line 4).
pub fn fake_quant_slice_with_range(
    xs: &mut [f32],
    vmin: f32,
    vmax: f32,
    bits: u32,
) -> Result<QParams> {
    let qp = QParams::from_range(vmin, vmax, bits)?;
    for x in xs.iter_mut() {
        *x = qp.roundtrip(*x);
    }
    Ok(qp)
}

/// Per-axis (axis 0 = output features) fake quantization of a rank-2
/// weight tensor — the paper's conv-channel scheme mapped to MLP rows.
pub fn fake_quant_per_axis(w: &mut Tensor, bits: u32) -> Result<Vec<QParams>> {
    if w.rank() != 2 {
        return Err(Error::Quant(format!("per-axis quant expects rank 2, got {}", w.rank())));
    }
    let rows = w.shape()[0];
    let cols = w.shape()[1];
    let data = w.data_mut();
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &mut data[r * cols..(r + 1) * cols];
        out.push(fake_quant_slice(row, bits)?);
    }
    Ok(out)
}

/// Quantize a slice to integer codes (for the int8 deployment engine).
pub fn quantize_codes(xs: &[f32], qp: QParams) -> Vec<i32> {
    xs.iter().map(|&x| qp.quantize(x) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_always_representable() {
        for bits in [2, 4, 8] {
            let qp = QParams::from_range(-3.7, 11.2, bits).unwrap();
            let z = qp.roundtrip(0.0);
            assert_eq!(z, 0.0, "bits={bits}: 0 -> {z}");
        }
    }

    #[test]
    fn codes_in_range() {
        let qp = QParams::from_range(-1.0, 1.0, 4).unwrap();
        for x in [-5.0f32, -1.0, -0.3, 0.0, 0.2, 1.0, 9.0] {
            let q = qp.quantize(x);
            assert!((0.0..=15.0).contains(&q), "{x} -> {q}");
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_delta() {
        let qp = QParams::from_range(-2.0, 2.0, 8).unwrap();
        for i in 0..1000 {
            let x = -2.0 + 4.0 * (i as f32 / 999.0);
            let err = (qp.roundtrip(x) - x).abs();
            assert!(err <= qp.delta + 1e-6, "x={x} err={err} delta={}", qp.delta);
        }
    }

    #[test]
    fn more_bits_less_error() {
        let xs: Vec<f32> = (0..256).map(|i| ((i as f32) * 0.37).sin() * 2.0).collect();
        let mut prev = f32::INFINITY;
        for bits in [2u32, 4, 6, 8, 12] {
            let mut ys = xs.clone();
            fake_quant_slice(&mut ys, bits).unwrap();
            let mse: f32 =
                xs.iter().zip(&ys).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / xs.len() as f32;
            assert!(mse <= prev + 1e-9, "bits={bits} mse={mse} prev={prev}");
            prev = mse;
        }
        assert!(prev < 1e-4, "12-bit mse should be tiny: {prev}");
    }

    #[test]
    fn wider_range_more_error() {
        // The paper's §4 mechanism: same values, wider monitored range =>
        // coarser grid => larger error.
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 / 63.0) - 0.5).collect();
        let mse = |vmin: f32, vmax: f32| {
            let qp = QParams::from_range(vmin, vmax, 8).unwrap();
            xs.iter().map(|&x| (qp.roundtrip(x) - x).powi(2)).sum::<f32>() / xs.len() as f32
        };
        assert!(mse(-0.5, 0.5) < mse(-8.0, 8.0));
    }

    #[test]
    fn degenerate_all_zero() {
        let mut xs = vec![0.0f32; 16];
        let qp = fake_quant_slice(&mut xs, 8).unwrap();
        assert!(xs.iter().all(|&x| x == 0.0));
        assert_eq!(qp.delta, 1.0);
    }

    #[test]
    fn per_axis_beats_per_tensor_on_mixed_scales() {
        // Row 0 tiny values, row 1 huge: per-axis keeps row 0 precise.
        let data = vec![0.01, -0.02, 0.015, -0.005, 10.0, -9.0, 8.0, -7.0];
        let mut w1 = Tensor::new(vec![2, 4], data).unwrap();
        let mut w2 = w1.clone();
        let orig = w1.clone();
        fake_quant_per_axis(&mut w1, 8).unwrap();
        fake_quant_slice(w2.data_mut(), 8).unwrap();
        let row_mse = |t: &Tensor| {
            t.data()[..4]
                .iter()
                .zip(&orig.data()[..4])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
        };
        assert!(row_mse(&w1) < row_mse(&w2) / 10.0, "{} vs {}", row_mse(&w1), row_mse(&w2));
    }

    #[test]
    fn i8_codes_pin_saturation_boundary() {
        // Symmetric 8-bit range: delta = 2/256, zero point = 128, so the
        // centered grid spans [-128, 127] and the most positive value
        // saturates at the +127 rail while -1.0 lands exactly on -128.
        let qp = QParams::from_range(-1.0, 1.0, 8).unwrap();
        assert_eq!(qp.zero_point, 128.0);
        assert_eq!(qp.quantize_i8(-1.0), -128);
        assert_eq!(qp.quantize_i8(1.0), 127);
        assert_eq!(qp.quantize_i8(0.0), 0);
        // Far outside the observed range the code pins to the rails
        // instead of wrapping — the clamp the int8 engine relies on.
        assert_eq!(qp.quantize_i8(-100.0), -128);
        assert_eq!(qp.quantize_i8(100.0), 127);
        // Asymmetric range: zero point 192 leaves only 63 positive codes
        // before the [0, 255] clip, and pushes the bottom of the grid to
        // -192, which the i8 clamp saturates at -128.
        let qp = QParams::from_range(-3.0, 1.0, 8).unwrap();
        assert_eq!(qp.zero_point, 192.0);
        assert_eq!(qp.quantize_i8(-3.0), -128, "grid bottom saturates the i8 rail");
        assert_eq!(qp.quantize_i8(1.0), 63, "grid top is clipped by quantize()");
        // The saturation crossover sits at code -128: one step above is
        // representable, one step below pins.
        let edge = qp.dequantize_i8(-128);
        assert_eq!(qp.quantize_i8(edge + qp.delta * 1.5), -127);
        assert_eq!(qp.quantize_i8(edge - qp.delta * 1.5), -128);
    }

    #[test]
    fn i8_roundtrip_error_bounded_off_the_rails() {
        // Inside the non-saturating span the floor-based quantizer's
        // round-trip error is bounded by one grid step.
        let qp = QParams::from_range(-2.0, 2.0, 8).unwrap();
        for i in 0..1000 {
            let x = -2.0 + 4.0 * (i as f32 / 999.0);
            let code = qp.quantize_i8(x);
            if code > -128 && code < 127 {
                let err = (qp.dequantize_i8(code) - x).abs();
                assert!(err <= qp.delta + 1e-6, "x={x} err={err} delta={}", qp.delta);
            }
        }
    }

    #[test]
    fn centered_codes_generalize_across_bitwidths() {
        // Symmetric 4-bit range: delta = 2/16, zero point = 8 — the
        // centered grid spans [-8, 7] and saturates at the rails exactly
        // like the i8 rule does at ±128/±127.
        let qp = QParams::from_range(-1.0, 1.0, 4).unwrap();
        assert_eq!(qp.zero_point, 8.0);
        assert_eq!(qp.quantize_code(-1.0, 4), -8);
        assert_eq!(qp.quantize_code(1.0, 4), 7);
        assert_eq!(qp.quantize_code(0.0, 4), 0);
        assert_eq!(qp.quantize_code(-100.0, 4), -8);
        assert_eq!(qp.quantize_code(100.0, 4), 7);
        // The 8-bit case is quantize_i8, code for code.
        let qp8 = QParams::from_range(-3.0, 1.0, 8).unwrap();
        for i in 0..100 {
            let x = -4.0 + 6.0 * (i as f32 / 99.0);
            assert_eq!(qp8.quantize_code(x, 8), qp8.quantize_i8(x));
        }
        // Asymmetric 4-bit range: the grid bottom saturates the signed
        // rail, mirroring the i8 test above.
        let qp = QParams::from_range(-3.0, 1.0, 4).unwrap();
        assert_eq!(qp.zero_point, 12.0);
        assert_eq!(qp.quantize_code(-3.0, 4), -8);
        assert_eq!(qp.quantize_code(1.0, 4), 3);
    }

    #[test]
    fn invalid_bits_rejected() {
        assert!(QParams::from_range(-1.0, 1.0, 0).is_err());
        assert!(QParams::from_range(-1.0, 1.0, 32).is_err());
    }
}

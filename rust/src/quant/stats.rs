//! Weight-distribution statistics — the analysis machinery behind the
//! paper's Figures 3 and 4 (weight spread vs quantization error) and
//! Table 3 (algorithm effect on quantization quality).

use crate::quant::affine::QParams;
use crate::runtime::ParamSet;

/// Summary of one parameter set's weight distribution.
#[derive(Debug, Clone)]
pub struct WeightStats {
    pub n: usize,
    pub min: f32,
    pub max: f32,
    pub mean: f32,
    pub std: f32,
    /// max - min: the "spread" the paper links to int8 error.
    pub spread: f32,
    /// Fraction of weights within one int8 delta of zero (narrowness).
    pub near_zero_frac: f32,
    /// Mean-squared int8 fake-quantization error of the weights.
    pub int8_mse: f32,
    /// Histogram over `bins` equal buckets spanning [min, max].
    pub histogram: Vec<usize>,
    pub bin_edges: (f32, f32),
}

/// Compute distribution stats over every weight matrix in a set
/// (biases excluded — the paper plots weight distributions).
pub fn weight_stats(params: &ParamSet, bins: usize) -> WeightStats {
    let mut values: Vec<f32> = Vec::new();
    for (name, t) in params.names.iter().zip(&params.tensors) {
        if t.rank() == 2 && (name.contains(".w") || name.contains("w")) {
            values.extend_from_slice(t.data());
        }
    }
    if values.is_empty() {
        for t in &params.tensors {
            values.extend_from_slice(t.data());
        }
    }
    let n = values.len();
    let min = values.iter().copied().fold(f32::INFINITY, f32::min);
    let max = values.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mean = values.iter().sum::<f32>() / n as f32;
    let var = values.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
    let std = var.sqrt();

    let qp = QParams::from_range(min, max, 8).expect("8-bit params");
    let mut int8_se = 0.0f64;
    let mut near_zero = 0usize;
    let mut histogram = vec![0usize; bins];
    let width = (max - min).max(1e-12);
    for &x in &values {
        let e = qp.roundtrip(x) - x;
        int8_se += (e as f64) * (e as f64);
        if x.abs() <= qp.delta {
            near_zero += 1;
        }
        let b = (((x - min) / width) * bins as f32) as usize;
        histogram[b.min(bins - 1)] += 1;
    }

    WeightStats {
        n,
        min,
        max,
        mean,
        std,
        spread: max - min,
        near_zero_frac: near_zero as f32 / n as f32,
        int8_mse: (int8_se / n as f64) as f32,
        histogram,
        bin_edges: (min, max),
    }
}

/// Render a terminal histogram (the harness prints these for Fig 3/4).
pub fn render_histogram(stats: &WeightStats, width: usize) -> String {
    let peak = stats.histogram.iter().copied().max().unwrap_or(1).max(1);
    let mut out = String::new();
    let bins = stats.histogram.len();
    for (i, &c) in stats.histogram.iter().enumerate() {
        let lo = stats.bin_edges.0
            + (stats.bin_edges.1 - stats.bin_edges.0) * i as f32 / bins as f32;
        let bar = "#".repeat((c * width + peak - 1) / peak);
        out.push_str(&format!("{lo:>8.3} | {bar}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::runtime::manifest::TensorSpec;
    use crate::tensor::Tensor;

    fn set_from(values: Vec<f32>) -> ParamSet {
        let n = values.len();
        ParamSet {
            names: vec!["q.w0".into()],
            tensors: vec![Tensor::new(vec![1, n], values).unwrap()],
        }
    }

    #[test]
    fn wider_distribution_higher_int8_mse() {
        let narrow = set_from((0..512).map(|i| ((i as f32) * 0.1).sin() * 0.1).collect());
        let wide = set_from((0..512).map(|i| ((i as f32) * 0.1).sin() * 5.0).collect());
        let sn = weight_stats(&narrow, 32);
        let sw = weight_stats(&wide, 32);
        assert!(sw.spread > sn.spread);
        assert!(sw.int8_mse > sn.int8_mse * 10.0, "{} vs {}", sw.int8_mse, sn.int8_mse);
    }

    #[test]
    fn histogram_sums_to_n() {
        let mut rng = Pcg32::new(3, 3);
        let specs = [TensorSpec { name: "q.w0".into(), shape: vec![32, 32] }];
        let p = ParamSet::init(&specs, &mut rng);
        let s = weight_stats(&p, 20);
        assert_eq!(s.histogram.iter().sum::<usize>(), s.n);
        assert_eq!(s.n, 1024);
    }

    #[test]
    fn render_is_nonempty_and_lines_match_bins() {
        let p = set_from((0..100).map(|i| i as f32 / 100.0 - 0.5).collect());
        let s = weight_stats(&p, 10);
        let r = render_histogram(&s, 40);
        assert_eq!(r.lines().count(), 10);
    }

    #[test]
    fn biases_excluded_from_weight_stats() {
        let p = ParamSet {
            names: vec!["q.w0".into(), "q.b0".into()],
            tensors: vec![
                Tensor::new(vec![2, 2], vec![0.1, -0.1, 0.2, -0.2]).unwrap(),
                Tensor::new(vec![2], vec![100.0, -100.0]).unwrap(),
            ],
        };
        let s = weight_stats(&p, 4);
        assert_eq!(s.n, 4);
        assert!(s.max < 1.0, "bias outliers must not leak into stats");
    }
}

//! The one numeric-format selector the whole deployment stack shares.
//!
//! The paper sweeps policy precision from 32 bits down to 2 (Fig. 6,
//! Table 2); [`Precision`] is how a caller names a point on that axis —
//! from the `quant/` codecs, through the [`crate::inference::Engine`]
//! instantiations, the ActorQ quantize-on-broadcast path, up to the
//! `--bits` sweeps in the experiment harness. Adding a future precision
//! (fp16 actors, per-layer mixes) means extending this enum and the
//! codec behind it — not forking a new engine type per format (int2
//! four-per-byte packing landed exactly that way, and the sub-int2
//! bitplane formats `Int(1)` / `Ternary` followed the same route).

use crate::error::{Error, Result};

/// Numeric format of a deployed policy copy.
///
/// `Int(b)` for `b >= 2` is the uniform-affine integer grid of
/// `quant::affine` at `b` bits (weights stored as centered codes;
/// activations dynamically quantized at 8 bits by the engines).
/// `Int(1)` is the XNOR-Net binary grid: weights are `{-1,+1}` sign
/// bitplanes with a per-layer scale, activations are mean-centered sign
/// bitplanes with per-row `(mu, alpha)`. `Ternary` is the TWN grid
/// `{-1,0,+1}`: a sign plane plus a nonzero-mask plane. `Fp32` is the
/// full-precision baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full-precision fp32 (the paper's baseline configuration).
    Fp32,
    /// `b`-bit integer grid, `b` in 1..=8 for the native engines.
    /// Widths 2..=8 are uniform-affine packed codes (two per byte at
    /// 3..=4 bits, four per byte at 2); width 1 is the binary sign
    /// bitplane (one bit per weight, 64 weights per `u64` word).
    Int(u32),
    /// Ternary `{-1,0,+1}` weights: a sign bitplane plus a nonzero-mask
    /// bitplane (two bits per weight), scale = mean |w| over the
    /// nonzero support (TWN-style, threshold 0.7 * mean |w|).
    Ternary,
}

impl Precision {
    /// The paper's headline deployment precision.
    pub const INT8: Precision = Precision::Int(8);
    /// The packed sub-byte precision introduced with the nibble codec.
    pub const INT4: Precision = Precision::Int(4);
    /// The XNOR-popcount binary precision (0.125 B/param).
    pub const INT1: Precision = Precision::Int(1);

    /// Map a CLI-style bitwidth to a precision (32 -> fp32). Ternary
    /// has no numeric width; see [`Precision::from_token`].
    pub fn from_bits(bits: u32) -> Precision {
        if bits >= 32 {
            Precision::Fp32
        } else {
            Precision::Int(bits)
        }
    }

    /// Parse a CLI/manifest token: a numeric bitwidth ("1".."32"),
    /// "fp32", "int<N>", or "t"/"ternary".
    pub fn from_token(tok: &str) -> Result<Precision> {
        let t = tok.trim();
        match t {
            "t" | "ternary" => return Ok(Precision::Ternary),
            "fp32" => return Ok(Precision::Fp32),
            _ => {}
        }
        let digits = t.strip_prefix("int").unwrap_or(t);
        match digits.parse::<u32>() {
            Ok(b) if b >= 1 => Ok(Precision::from_bits(b)),
            _ => Err(Error::Config(format!(
                "bad precision token '{tok}' (expected a bitwidth, 'intN', 'fp32', or 't'/'ternary')"
            ))),
        }
    }

    /// Storage/compute bitwidth (32 for fp32, 2 for ternary — the
    /// sign+mask planes spend two bits per weight).
    pub fn bits(&self) -> u32 {
        match self {
            Precision::Fp32 => 32,
            Precision::Int(b) => *b,
            Precision::Ternary => 2,
        }
    }

    /// Human/bench label: "fp32", "int8", ..., "int1", "ternary".
    pub fn label(&self) -> String {
        match self {
            Precision::Fp32 => "fp32".into(),
            Precision::Int(b) => format!("int{b}"),
            Precision::Ternary => "ternary".into(),
        }
    }

    /// Inverse of [`Precision::label`] (used by snapshot manifests).
    pub fn from_label(label: &str) -> Result<Precision> {
        match label {
            "fp32" => Ok(Precision::Fp32),
            "ternary" => Ok(Precision::Ternary),
            _ => match label.strip_prefix("int").map(str::parse::<u32>) {
                Some(Ok(b)) if (1..32).contains(&b) => Ok(Precision::Int(b)),
                _ => Err(Error::Quant(format!("unknown precision label '{label}'"))),
            },
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, Precision::Int(_) | Precision::Ternary)
    }

    /// Whether the weights of this precision are stored as sign/mask
    /// bitplanes fed to the XNOR-popcount kernels (vs packed affine
    /// codes on the SWAR unpack kernels).
    pub fn is_bitplane(&self) -> bool {
        matches!(self, Precision::Int(1) | Precision::Ternary)
    }

    /// Whether the native deployment engines implement this precision
    /// (fp32, an integer grid the i8/nibble/crumb codecs can store, or
    /// a bitplane format of the XNOR kernels).
    pub fn engine_supported(&self) -> bool {
        matches!(self, Precision::Fp32 | Precision::Int(1..=8) | Precision::Ternary)
    }

    /// Error unless [`Precision::engine_supported`].
    pub fn validate_for_engine(&self) -> Result<()> {
        if self.engine_supported() {
            Ok(())
        } else {
            Err(Error::Quant(format!(
                "precision {} has no native engine (supported: fp32, int1..=int8, ternary)",
                self.label()
            )))
        }
    }

    /// Bytes of weight storage per parameter in the deployment
    /// representation: 4 for fp32, 1 per i8 code, 0.5 for packed
    /// nibble codes (two per byte, bits 3..=4), 0.25 for packed crumb
    /// codes (bits 2) and for ternary (sign + mask planes), 0.125 for
    /// the binary sign bitplane. Biases stay fp32 in every engine and
    /// are accounted separately.
    pub fn weight_bytes_per_param(&self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Int(1) => 0.125,
            Precision::Int(b) if *b <= 2 => 0.25,
            Precision::Int(b) if *b <= 4 => 0.5,
            Precision::Int(_) => 1.0,
            Precision::Ternary => 0.25,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_and_bits() {
        assert_eq!(Precision::Fp32.label(), "fp32");
        assert_eq!(Precision::Int(8).label(), "int8");
        assert_eq!(Precision::Int(4).label(), "int4");
        assert_eq!(Precision::Int(1).label(), "int1");
        assert_eq!(Precision::Ternary.label(), "ternary");
        assert_eq!(Precision::Fp32.bits(), 32);
        assert_eq!(Precision::INT4.bits(), 4);
        assert_eq!(Precision::INT1.bits(), 1);
        assert_eq!(Precision::Ternary.bits(), 2);
        assert_eq!(Precision::from_bits(32), Precision::Fp32);
        assert_eq!(Precision::from_bits(8), Precision::INT8);
        assert_eq!(Precision::from_bits(1), Precision::INT1);
    }

    #[test]
    fn label_round_trips() {
        for p in [
            Precision::Fp32,
            Precision::Int(1),
            Precision::Int(2),
            Precision::Int(8),
            Precision::Ternary,
        ] {
            assert_eq!(Precision::from_label(&p.label()).unwrap(), p);
        }
        assert!(Precision::from_label("int0").is_err());
        assert!(Precision::from_label("fp16").is_err());
        assert!(Precision::from_label("").is_err());
    }

    #[test]
    fn token_parse() {
        assert_eq!(Precision::from_token("8").unwrap(), Precision::INT8);
        assert_eq!(Precision::from_token("1").unwrap(), Precision::INT1);
        assert_eq!(Precision::from_token("32").unwrap(), Precision::Fp32);
        assert_eq!(Precision::from_token("t").unwrap(), Precision::Ternary);
        assert_eq!(Precision::from_token("ternary").unwrap(), Precision::Ternary);
        assert_eq!(Precision::from_token("int4").unwrap(), Precision::INT4);
        assert_eq!(Precision::from_token("fp32").unwrap(), Precision::Fp32);
        assert!(Precision::from_token("0").is_err());
        assert!(Precision::from_token("x").is_err());
    }

    #[test]
    fn engine_support_window() {
        assert!(Precision::Fp32.engine_supported());
        for b in 1..=8 {
            assert!(Precision::Int(b).engine_supported(), "int{b}");
        }
        assert!(Precision::Ternary.engine_supported());
        assert!(!Precision::Int(0).engine_supported());
        assert!(!Precision::Int(16).engine_supported());
        assert!(Precision::Int(16).validate_for_engine().is_err());
        assert!(Precision::INT4.validate_for_engine().is_ok());
        assert!(Precision::INT1.validate_for_engine().is_ok());
        // bitplane formats are exactly int1 + ternary
        assert!(Precision::INT1.is_bitplane());
        assert!(Precision::Ternary.is_bitplane());
        assert!(!Precision::Int(2).is_bitplane());
        assert!(!Precision::Fp32.is_bitplane());
    }

    #[test]
    fn packed_widths_shrink_weight_bytes() {
        assert_eq!(Precision::Fp32.weight_bytes_per_param(), 4.0);
        assert_eq!(Precision::Int(8).weight_bytes_per_param(), 1.0);
        assert_eq!(Precision::Int(5).weight_bytes_per_param(), 1.0);
        assert_eq!(Precision::Int(4).weight_bytes_per_param(), 0.5);
        assert_eq!(Precision::Int(3).weight_bytes_per_param(), 0.5);
        // the four-per-byte crumb codec quarters the traffic
        assert_eq!(Precision::Int(2).weight_bytes_per_param(), 0.25);
        // two planes at one bit each: same 0.25 for ternary
        assert_eq!(Precision::Ternary.weight_bytes_per_param(), 0.25);
        // the sign bitplane is the floor: one bit per weight
        assert_eq!(Precision::Int(1).weight_bytes_per_param(), 0.125);
    }
}

//! The one numeric-format selector the whole deployment stack shares.
//!
//! The paper sweeps policy precision from 32 bits down to 2 (Fig. 6,
//! Table 2); [`Precision`] is how a caller names a point on that axis —
//! from the `quant/` codecs, through the [`crate::inference::Engine`]
//! instantiations, the ActorQ quantize-on-broadcast path, up to the
//! `--bits` sweeps in the experiment harness. Adding a future precision
//! (fp16 actors, per-layer mixes) means extending this enum and the
//! codec behind it — not forking a new engine type per format (int2
//! four-per-byte packing landed exactly that way).

use crate::error::{Error, Result};

/// Numeric format of a deployed policy copy.
///
/// `Int(b)` is the uniform-affine integer grid of `quant::affine` at `b`
/// bits (weights stored as centered codes; activations dynamically
/// quantized at 8 bits by the engines). `Fp32` is the full-precision
/// baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full-precision fp32 (the paper's baseline configuration).
    Fp32,
    /// `b`-bit uniform affine integer grid, `b` in 2..=8 for the native
    /// engines (sub-byte widths are stored packed: two codes per byte
    /// at 3..=4 bits, four per byte at 2).
    Int(u32),
}

impl Precision {
    /// The paper's headline deployment precision.
    pub const INT8: Precision = Precision::Int(8);
    /// The packed sub-byte precision introduced with the nibble codec.
    pub const INT4: Precision = Precision::Int(4);

    /// Map a CLI-style bitwidth to a precision (32 -> fp32).
    pub fn from_bits(bits: u32) -> Precision {
        if bits >= 32 {
            Precision::Fp32
        } else {
            Precision::Int(bits)
        }
    }

    /// Storage/compute bitwidth (32 for fp32).
    pub fn bits(&self) -> u32 {
        match self {
            Precision::Fp32 => 32,
            Precision::Int(b) => *b,
        }
    }

    /// Human/bench label: "fp32", "int8", "int4", ...
    pub fn label(&self) -> String {
        match self {
            Precision::Fp32 => "fp32".into(),
            Precision::Int(b) => format!("int{b}"),
        }
    }

    pub fn is_quantized(&self) -> bool {
        matches!(self, Precision::Int(_))
    }

    /// Whether the native deployment engines implement this precision
    /// (fp32, or an integer grid the i8/nibble codecs can store).
    pub fn engine_supported(&self) -> bool {
        matches!(self, Precision::Fp32 | Precision::Int(2..=8))
    }

    /// Error unless [`Precision::engine_supported`].
    pub fn validate_for_engine(&self) -> Result<()> {
        if self.engine_supported() {
            Ok(())
        } else {
            Err(Error::Quant(format!(
                "precision {} has no native engine (supported: fp32, int2..=int8)",
                self.label()
            )))
        }
    }

    /// Bytes of weight storage per parameter in the deployment
    /// representation: 4 for fp32, 1 per i8 code, 0.5 for packed
    /// nibble codes (two per byte, bits 3..=4), 0.25 for packed crumb
    /// codes (four per byte, bits 2). Biases stay fp32 in every engine
    /// and are accounted separately.
    pub fn weight_bytes_per_param(&self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Int(b) if *b <= 2 => 0.25,
            Precision::Int(b) if *b <= 4 => 0.5,
            Precision::Int(_) => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_and_bits() {
        assert_eq!(Precision::Fp32.label(), "fp32");
        assert_eq!(Precision::Int(8).label(), "int8");
        assert_eq!(Precision::Int(4).label(), "int4");
        assert_eq!(Precision::Fp32.bits(), 32);
        assert_eq!(Precision::INT4.bits(), 4);
        assert_eq!(Precision::from_bits(32), Precision::Fp32);
        assert_eq!(Precision::from_bits(8), Precision::INT8);
    }

    #[test]
    fn engine_support_window() {
        assert!(Precision::Fp32.engine_supported());
        for b in 2..=8 {
            assert!(Precision::Int(b).engine_supported(), "int{b}");
        }
        assert!(!Precision::Int(1).engine_supported());
        assert!(!Precision::Int(16).engine_supported());
        assert!(Precision::Int(16).validate_for_engine().is_err());
        assert!(Precision::INT4.validate_for_engine().is_ok());
    }

    #[test]
    fn packed_widths_shrink_weight_bytes() {
        assert_eq!(Precision::Fp32.weight_bytes_per_param(), 4.0);
        assert_eq!(Precision::Int(8).weight_bytes_per_param(), 1.0);
        assert_eq!(Precision::Int(5).weight_bytes_per_param(), 1.0);
        assert_eq!(Precision::Int(4).weight_bytes_per_param(), 0.5);
        assert_eq!(Precision::Int(3).weight_bytes_per_param(), 0.5);
        // the four-per-byte crumb codec quarters the traffic
        assert_eq!(Precision::Int(2).weight_bytes_per_param(), 0.25);
    }
}

//! Post-training quantization over parameter sets (paper Algorithm 1).
//!
//! Takes a trained fp32 `ParamSet` and returns a quantized copy:
//! * `Fp16` — IEEE half rounding of every parameter.
//! * `Int(n)` — n-bit uniform affine, per-tensor on weight matrices and
//!   biases (the paper's FC scheme; per-axis is exposed separately and
//!   benchmarked as an ablation).
//!
//! Evaluation then runs the same `act` program with quantized weights —
//! quantization error enters exactly as in the paper (weights only;
//! activations stay fp32 in PTQ).

use crate::error::Result;
use crate::quant::affine::{fake_quant_per_axis, fake_quant_slice};
use crate::quant::fp16::fp16_quant_slice;
use crate::runtime::ParamSet;

/// A PTQ method selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PtqMethod {
    /// No-op (fp32 baseline) — lets sweeps treat fp32 uniformly.
    Fp32,
    /// IEEE-754 half rounding.
    Fp16,
    /// n-bit uniform affine, per-tensor.
    Int(u32),
    /// n-bit uniform affine, per-axis on rank-2 tensors (ablation).
    IntPerAxis(u32),
}

impl PtqMethod {
    pub fn label(&self) -> String {
        match self {
            PtqMethod::Fp32 => "fp32".into(),
            PtqMethod::Fp16 => "fp16".into(),
            PtqMethod::Int(n) => format!("int{n}"),
            PtqMethod::IntPerAxis(n) => format!("int{n}pa"),
        }
    }
}

/// Quantize a copy of `params` with `method`.
pub fn quantize_params(params: &ParamSet, method: PtqMethod) -> Result<ParamSet> {
    let mut out = params.clone();
    match method {
        PtqMethod::Fp32 => {}
        PtqMethod::Fp16 => {
            for t in out.tensors.iter_mut() {
                fp16_quant_slice(t.data_mut());
            }
        }
        PtqMethod::Int(bits) => {
            for t in out.tensors.iter_mut() {
                if t.is_empty() {
                    continue;
                }
                fake_quant_slice(t.data_mut(), bits)?;
            }
        }
        PtqMethod::IntPerAxis(bits) => {
            for t in out.tensors.iter_mut() {
                if t.is_empty() {
                    continue;
                }
                if t.rank() == 2 {
                    fake_quant_per_axis(t, bits)?;
                } else {
                    fake_quant_slice(t.data_mut(), bits)?;
                }
            }
        }
    }
    Ok(out)
}

/// Paper Table-2 relative error: E = (fp32 - quant) / fp32 * 100.
/// (Negative error = quantized model outperformed the baseline.)
pub fn relative_error_pct(fp32_reward: f32, quant_reward: f32) -> f32 {
    if fp32_reward.abs() < 1e-9 {
        return 0.0;
    }
    (fp32_reward - quant_reward) / fp32_reward.abs() * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::runtime::manifest::TensorSpec;

    fn params() -> ParamSet {
        let specs = vec![
            TensorSpec { name: "q.w0".into(), shape: vec![8, 16] },
            TensorSpec { name: "q.b0".into(), shape: vec![16] },
            TensorSpec { name: "q.w1".into(), shape: vec![16, 4] },
            TensorSpec { name: "q.b1".into(), shape: vec![4] },
        ];
        let mut rng = Pcg32::new(5, 5);
        ParamSet::init(&specs, &mut rng)
    }

    fn mse(a: &ParamSet, b: &ParamSet) -> f32 {
        let mut s = 0.0;
        let mut n = 0;
        for (x, y) in a.tensors.iter().zip(&b.tensors) {
            for (u, v) in x.data().iter().zip(y.data()) {
                s += (u - v) * (u - v);
                n += 1;
            }
        }
        s / n as f32
    }

    #[test]
    fn fp32_is_identity() {
        let p = params();
        let q = quantize_params(&p, PtqMethod::Fp32).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn error_ordering_matches_paper() {
        // fp16 << int8 << int4 << int2 error, all nonzero but bounded.
        let p = params();
        let e16 = mse(&p, &quantize_params(&p, PtqMethod::Fp16).unwrap());
        let e8 = mse(&p, &quantize_params(&p, PtqMethod::Int(8)).unwrap());
        let e4 = mse(&p, &quantize_params(&p, PtqMethod::Int(4)).unwrap());
        let e2 = mse(&p, &quantize_params(&p, PtqMethod::Int(2)).unwrap());
        assert!(e16 < e8 && e8 < e4 && e4 < e2, "{e16} {e8} {e4} {e2}");
    }

    #[test]
    fn per_axis_no_worse_than_per_tensor() {
        let p = params();
        let pt = mse(&p, &quantize_params(&p, PtqMethod::Int(4)).unwrap());
        let pa = mse(&p, &quantize_params(&p, PtqMethod::IntPerAxis(4)).unwrap());
        assert!(pa <= pt * 1.05, "per-axis {pa} vs per-tensor {pt}");
    }

    #[test]
    fn shapes_preserved() {
        let p = params();
        let q = quantize_params(&p, PtqMethod::Int(8)).unwrap();
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a.shape(), b.shape());
        }
        assert_eq!(p.names, q.names);
    }

    #[test]
    fn relative_error_signs() {
        assert!(relative_error_pct(100.0, 90.0) > 0.0);
        assert!(relative_error_pct(100.0, 110.0) < 0.0);
        // negative baselines (Pong-style scores) keep the sign convention:
        // doing worse than baseline is positive error
        assert!(relative_error_pct(-100.0, -150.0) > 0.0);
        assert_eq!(relative_error_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn labels() {
        assert_eq!(PtqMethod::Int(8).label(), "int8");
        assert_eq!(PtqMethod::Fp16.label(), "fp16");
        assert_eq!(PtqMethod::IntPerAxis(4).label(), "int4pa");
    }
}

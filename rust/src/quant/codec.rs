//! Code storage for the quantized deployment engines: one codec per
//! storage class, behind a single enum so the engines are generic over
//! bitwidth.
//!
//! * bits 5..=8 — one centered i8 code per byte (the PR-3 layout).
//! * bits 3..=4 — two centered codes per byte, 4-bit two's complement:
//!   element `2k` in the low nibble, `2k+1` in the high nibble. This is
//!   the packing that halves weight traffic again below int8 — the
//!   memory-bandwidth lever behind the sub-8-bit deployment study.
//! * bits 2 — four centered codes per byte, 2-bit two's complement:
//!   element `4k + j` in bits `2j..2j+2` of byte `k`, quartering weight
//!   traffic relative to int8.
//! * bits 1 (binary) — a sign bitplane: element `8k + j` is bit `j` of
//!   byte `k`, set iff the code is `-1` (clear = `+1`), 0.125 B/param.
//!   The XNOR-popcount GEMM consumes 64 of these per `u64` load.
//! * ternary — two bitplanes, mask-then-sign: the nonzero-mask plane
//!   (bit set iff the code is nonzero) followed by the sign plane (bit
//!   set iff the code is `-1`), each `ceil(n/8)` bytes. Canonical
//!   encodings keep every sign bit clear where the mask bit is clear.
//!
//! The codes themselves come from [`crate::quant::QParams::quantize_code`]
//! (centered on the zero point, saturating at the signed rails), so
//! every consumer — scalar GEMV, packed GEMM, broadcast — shares one
//! quantization rule. Pack/unpack is lossless for every representable
//! code (pinned by the exhaustive tests below and the property suite in
//! `rust/tests/engine_parity.rs`).
//!
//! Two unpack speeds, one result:
//!
//! * the scalar accessors ([`nib4_lo`]/[`nib4_hi`]/[`crumb2`] and the
//!   `*_into` element-offset unpackers) handle arbitrary, possibly
//!   mid-byte element ranges — the reference path;
//! * the SWAR bulk unpackers ([`unpack16_nib4`], [`unpack32_crumb2`]
//!   and the byte-aligned [`unpack_block_nib4`]/[`unpack_block_crumb2`])
//!   expand 16 (nibbles) or 32 (crumbs) codes per `u64` load with
//!   shift/mask lane arithmetic and no per-code branches — the hot path
//!   behind the panel-major prepacked GEMM. Scalar == SWAR for every
//!   byte pattern (exhaustively tested below).

use crate::error::{Error, Result};
use crate::quant::Precision;

/// Packed storage bytes for `len` codes at `bits` (the [`CodeBuf`]
/// layout rule in one place: eight per byte at 1 bit, four per byte at
/// 2, two per byte at 3..=4, one per byte at 5..=8).
pub fn packed_len(len: usize, bits: u32) -> usize {
    if bits == 1 {
        len.div_ceil(8)
    } else if bits <= 2 {
        len.div_ceil(4)
    } else if bits <= 4 {
        len.div_ceil(2)
    } else {
        len
    }
}

/// Bytes of one bitplane over `len` elements (eight bits per byte).
pub fn plane_len(len: usize) -> usize {
    len.div_ceil(8)
}

/// Packed storage bytes for `len` codes of a quantized precision —
/// ternary has no single numeric width ([`packed_len`] can't name it):
/// its wire form is two full bitplanes, mask then sign.
pub fn packed_len_for(len: usize, precision: Precision) -> usize {
    match precision {
        Precision::Ternary => 2 * plane_len(len),
        p => packed_len(len, p.bits()),
    }
}

/// Sign-extend the low nibble of a packed byte to an i8 code.
#[inline]
pub fn nib4_lo(byte: u8) -> i8 {
    ((byte as i8) << 4) >> 4
}

/// Sign-extend the high nibble of a packed byte to an i8 code.
#[inline]
pub fn nib4_hi(byte: u8) -> i8 {
    (byte as i8) >> 4
}

/// Sign-extend 2-bit code `j` (0..=3, low bits first) of a packed byte.
#[inline]
pub fn crumb2(byte: u8, j: usize) -> i8 {
    (((byte >> (2 * j)) as i8) << 6) >> 6
}

/// Per-byte lane masks for the SWAR unpackers: low nibble / crumb of
/// every byte, and the sign bit of each 4-bit / 2-bit lane.
const LANES_NIB: u64 = 0x0F0F_0F0F_0F0F_0F0F;
const SIGNS_NIB: u64 = 0x0808_0808_0808_0808;
const LANES_CRUMB: u64 = 0x0303_0303_0303_0303;
const SIGNS_CRUMB: u64 = 0x0202_0202_0202_0202;

/// Sign-extend a 4-bit value sitting in the low nibble of every byte
/// lane: where lane bit 3 is set, fill bits 4..=7 of that lane. The mask
/// `m` has at most bit 3 per byte, so every shift stays inside its lane —
/// no cross-byte carries, no branches.
#[inline]
fn sext4_lanes(v: u64) -> u64 {
    let m = v & SIGNS_NIB;
    v | (m << 1) | (m << 2) | (m << 3) | (m << 4)
}

/// Sign-extend a 2-bit value in the low crumb of every byte lane (fill
/// bits 2..=7 where lane bit 1 is set; shifts stay inside the lane).
#[inline]
fn sext2_lanes(v: u64) -> u64 {
    let m = v & SIGNS_CRUMB;
    v | (m << 1) | (m << 2) | (m << 3) | (m << 4) | (m << 5) | (m << 6)
}

/// Expand 16 packed 4-bit codes from one little-endian `u64` load: split
/// the word into low-nibble and high-nibble byte streams, sign-extend
/// all 8 lanes of each stream at once, and interleave back to element
/// order. Bit-identical to 16 [`nib4_lo`]/[`nib4_hi`] calls.
#[inline]
pub fn unpack16_nib4(word: u64, out: &mut [i8; 16]) {
    let lo = sext4_lanes(word & LANES_NIB).to_le_bytes();
    let hi = sext4_lanes((word >> 4) & LANES_NIB).to_le_bytes();
    for k in 0..8 {
        out[2 * k] = lo[k] as i8;
        out[2 * k + 1] = hi[k] as i8;
    }
}

/// Expand 32 packed 2-bit codes from one little-endian `u64` load (four
/// crumb streams, sign-extended lane-parallel, interleaved back).
/// Bit-identical to 32 [`crumb2`] calls.
#[inline]
pub fn unpack32_crumb2(word: u64, out: &mut [i8; 32]) {
    let s0 = sext2_lanes(word & LANES_CRUMB).to_le_bytes();
    let s1 = sext2_lanes((word >> 2) & LANES_CRUMB).to_le_bytes();
    let s2 = sext2_lanes((word >> 4) & LANES_CRUMB).to_le_bytes();
    let s3 = sext2_lanes((word >> 6) & LANES_CRUMB).to_le_bytes();
    for k in 0..8 {
        out[4 * k] = s0[k] as i8;
        out[4 * k + 1] = s1[k] as i8;
        out[4 * k + 2] = s2[k] as i8;
        out[4 * k + 3] = s3[k] as i8;
    }
}

/// Bulk-unpack the first `n` nibble codes of a byte-aligned packed
/// stream into `out[..n]`: full `u64` loads through [`unpack16_nib4`],
/// then one masked partial load for the tail. `packed` must hold at
/// least `n.div_ceil(2)` bytes; the element range always starts at a
/// byte boundary (the panel-major layout pads panels so this holds — a
/// mid-byte start needs the scalar [`unpack_nib4_into`]).
pub fn unpack_block_nib4(packed: &[u8], n: usize, out: &mut [i8]) {
    debug_assert!(packed.len() >= n.div_ceil(2) && out.len() >= n);
    let mut buf = [0i8; 16];
    let mut done = 0usize;
    let mut byte = 0usize;
    while n - done >= 16 {
        let word = u64::from_le_bytes(packed[byte..byte + 8].try_into().expect("8-byte chunk"));
        unpack16_nib4(word, &mut buf);
        out[done..done + 16].copy_from_slice(&buf);
        done += 16;
        byte += 8;
    }
    if done < n {
        let rest = n - done;
        let nb = rest.div_ceil(2);
        let mut tail = [0u8; 8];
        tail[..nb].copy_from_slice(&packed[byte..byte + nb]);
        unpack16_nib4(u64::from_le_bytes(tail), &mut buf);
        out[done..n].copy_from_slice(&buf[..rest]);
    }
}

/// Bulk-unpack the first `n` crumb codes of a byte-aligned packed stream
/// into `out[..n]` (32 codes per `u64` load; `packed` must hold at least
/// `n.div_ceil(4)` bytes).
pub fn unpack_block_crumb2(packed: &[u8], n: usize, out: &mut [i8]) {
    debug_assert!(packed.len() >= n.div_ceil(4) && out.len() >= n);
    let mut buf = [0i8; 32];
    let mut done = 0usize;
    let mut byte = 0usize;
    while n - done >= 32 {
        let word = u64::from_le_bytes(packed[byte..byte + 8].try_into().expect("8-byte chunk"));
        unpack32_crumb2(word, &mut buf);
        out[done..done + 32].copy_from_slice(&buf);
        done += 32;
        byte += 8;
    }
    if done < n {
        let rest = n - done;
        let nb = rest.div_ceil(4);
        let mut tail = [0u8; 8];
        tail[..nb].copy_from_slice(&packed[byte..byte + nb]);
        unpack32_crumb2(u64::from_le_bytes(tail), &mut buf);
        out[done..n].copy_from_slice(&buf[..rest]);
    }
}

/// Pack centered codes (each in [-8, 7]) two per byte; an odd tail
/// leaves the final high nibble zero.
pub fn pack_nib4(codes: &[i8]) -> Vec<u8> {
    debug_assert!(codes.iter().all(|&c| (-8..=7).contains(&c)), "nib4 code out of range");
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    for (i, &c) in codes.iter().enumerate() {
        let nib = (c as u8) & 0x0F;
        if i % 2 == 0 {
            out[i / 2] |= nib;
        } else {
            out[i / 2] |= nib << 4;
        }
    }
    out
}

/// Unpack `out.len()` consecutive codes starting at element offset
/// `start` (which may be odd — sub-byte rows need not be byte-aligned).
#[inline]
pub fn unpack_nib4_into(packed: &[u8], start: usize, out: &mut [i8]) {
    for (j, o) in out.iter_mut().enumerate() {
        let idx = start + j;
        let byte = packed[idx / 2];
        *o = if idx % 2 == 0 { nib4_lo(byte) } else { nib4_hi(byte) };
    }
}

/// Pack centered codes (each in [-2, 1]) four per byte; a partial tail
/// byte keeps its upper crumbs zero.
pub fn pack_crumb2(codes: &[i8]) -> Vec<u8> {
    debug_assert!(codes.iter().all(|&c| (-2..=1).contains(&c)), "crumb2 code out of range");
    let mut out = vec![0u8; codes.len().div_ceil(4)];
    for (i, &c) in codes.iter().enumerate() {
        out[i / 4] |= ((c as u8) & 0x03) << (2 * (i % 4));
    }
    out
}

/// Unpack `out.len()` consecutive 2-bit codes starting at element offset
/// `start` (any crumb position — rows need not be byte-aligned).
#[inline]
pub fn unpack_crumb2_into(packed: &[u8], start: usize, out: &mut [i8]) {
    for (j, o) in out.iter_mut().enumerate() {
        let idx = start + j;
        *o = crumb2(packed[idx / 4], idx % 4);
    }
}

/// One bitplane code: bit `i % 8` of byte `i / 8`, LSB-first.
#[inline]
pub fn plane_bit(plane: &[u8], i: usize) -> bool {
    (plane[i / 8] >> (i % 8)) & 1 == 1
}

/// Decode one binary code from a sign plane: bit set = `-1`, clear =
/// `+1` (the XNOR convention — both operand planes mark *negative*).
#[inline]
pub fn bit1_get(plane: &[u8], i: usize) -> i8 {
    if plane_bit(plane, i) {
        -1
    } else {
        1
    }
}

/// Decode one ternary code from (mask, sign) planes: `0` where the mask
/// bit is clear, else `-1`/`+1` by the sign bit.
#[inline]
pub fn tern_get(mask: &[u8], sign: &[u8], i: usize) -> i8 {
    if !plane_bit(mask, i) {
        0
    } else if plane_bit(sign, i) {
        -1
    } else {
        1
    }
}

/// Pack binary codes (each `-1` or `+1`) into a sign plane; pad bits of
/// a partial tail byte stay zero (reading as `+1` but never visited).
pub fn pack_bit1(codes: &[i8]) -> Vec<u8> {
    debug_assert!(codes.iter().all(|&c| c == -1 || c == 1), "bit1 code outside {{-1,+1}}");
    let mut plane = vec![0u8; plane_len(codes.len())];
    for (i, &c) in codes.iter().enumerate() {
        if c < 0 {
            plane[i / 8] |= 1 << (i % 8);
        }
    }
    plane
}

/// Pack ternary codes (each in `{-1, 0, +1}`) into the canonical
/// mask-then-sign wire form: sign bits are set only where the mask bit
/// is, and pad bits of partial tail bytes stay zero in both planes.
pub fn pack_tern(codes: &[i8]) -> Vec<u8> {
    debug_assert!(codes.iter().all(|&c| (-1..=1).contains(&c)), "tern code outside {{-1,0,+1}}");
    let pl = plane_len(codes.len());
    let mut planes = vec![0u8; 2 * pl];
    for (i, &c) in codes.iter().enumerate() {
        if c != 0 {
            planes[i / 8] |= 1 << (i % 8);
            if c < 0 {
                planes[pl + i / 8] |= 1 << (i % 8);
            }
        }
    }
    planes
}

/// Reject set bits past logical position `len` in a bitplane (the
/// packers always leave pad bits zero, so anything else is corruption —
/// and the XNOR kernel relies on zero pads contributing nothing).
fn check_plane_padding(plane: &[u8], len: usize, which: &str) -> Result<()> {
    for i in len..plane.len() * 8 {
        if plane_bit(plane, i) {
            return Err(Error::Config(format!("codebuf {which}-plane tail padding bit is non-zero")));
        }
    }
    Ok(())
}

/// XNOR-Net weight binarization: codes `sign(w)` (with `sign(0) = +1`)
/// and the per-tensor scale `alpha = mean |w|` that minimizes
/// `||w - alpha * sign(w)||^2`. An all-zero tensor yields `alpha = 0`
/// (every dequantized weight is exactly 0 regardless of sign codes).
pub fn binarize(w: &[f32]) -> (Vec<i8>, f32) {
    let codes: Vec<i8> = w.iter().map(|&x| if x < 0.0 { -1 } else { 1 }).collect();
    let alpha = if w.is_empty() {
        0.0
    } else {
        w.iter().map(|x| x.abs() as f64).sum::<f64>() / w.len() as f64
    };
    (codes, alpha as f32)
}

/// TWN weight ternarization: threshold `0.7 * mean |w|`, codes
/// `sign(w)` where `|w| > thr` else 0, scale `alpha = mean |w|` over
/// the nonzero support (0 when nothing survives the threshold).
pub fn ternarize(w: &[f32]) -> (Vec<i8>, f32) {
    let mean_abs = if w.is_empty() {
        0.0
    } else {
        w.iter().map(|x| x.abs() as f64).sum::<f64>() / w.len() as f64
    };
    let thr = 0.7 * mean_abs;
    let mut codes = Vec::with_capacity(w.len());
    let (mut sum, mut nnz) = (0f64, 0usize);
    for &x in w {
        if (x.abs() as f64) > thr {
            codes.push(if x < 0.0 { -1 } else { 1 });
            sum += x.abs() as f64;
            nnz += 1;
        } else {
            codes.push(0);
        }
    }
    let alpha = if nnz == 0 { 0.0 } else { sum / nnz as f64 };
    (codes, alpha as f32)
}

/// Storage for one tensor's centered integer codes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodeBuf {
    /// One code per byte (bits 5..=8).
    I8(Vec<i8>),
    /// Two 4-bit two's-complement codes per byte (bits 3..=4); the
    /// second field is the logical element count.
    Nib4(Vec<u8>, usize),
    /// Four 2-bit two's-complement codes per byte (bits 2); the second
    /// field is the logical element count.
    Crumb2(Vec<u8>, usize),
    /// Binary sign bitplane (bits 1): bit set = code `-1`; the second
    /// field is the logical element count.
    Bit1(Vec<u8>, usize),
    /// Ternary mask+sign bitplanes concatenated mask-first (each
    /// [`plane_len`] bytes); the second field is the logical count.
    Tern(Vec<u8>, usize),
}

impl CodeBuf {
    /// Pack `codes` for a `bits`-wide grid (codes must already be
    /// centered and clipped to the signed range for `bits`; at bits 1
    /// that means `{-1,+1}` sign codes).
    pub fn from_codes(codes: &[i8], bits: u32) -> CodeBuf {
        if bits == 1 {
            CodeBuf::Bit1(pack_bit1(codes), codes.len())
        } else if bits <= 2 {
            CodeBuf::Crumb2(pack_crumb2(codes), codes.len())
        } else if bits <= 4 {
            CodeBuf::Nib4(pack_nib4(codes), codes.len())
        } else {
            CodeBuf::I8(codes.to_vec())
        }
    }

    /// Pack `codes` for a quantized precision — the precision-keyed
    /// twin of [`CodeBuf::from_codes`], needed because ternary has no
    /// numeric width of its own.
    pub fn from_codes_for(codes: &[i8], precision: Precision) -> CodeBuf {
        match precision {
            Precision::Ternary => CodeBuf::Tern(pack_tern(codes), codes.len()),
            p => CodeBuf::from_codes(codes, p.bits()),
        }
    }

    /// Deserialize packed bytes for a `bits`-wide grid of `len` logical
    /// codes, **validated**: the byte count must match
    /// [`packed_len`]`(len, bits)`, every code must sit on the centered
    /// signed rail for `bits`, and padding nibbles/crumbs of a partial
    /// tail byte must be zero (the canonical encoding
    /// [`pack_nib4`]/[`pack_crumb2`] emit). Violations are
    /// [`Error::Config`] — before this constructor existed, a
    /// short or corrupt buffer handed to a consumer would only surface
    /// as an index panic deep inside `PanelStore` packing, which is the
    /// latent bug class the snapshot client must never hit.
    pub fn from_packed(bytes: Vec<u8>, len: usize, bits: u32) -> Result<CodeBuf> {
        if !(1..=8).contains(&bits) {
            return Err(Error::Config(format!("codebuf bits {bits} outside the engine range 1..=8")));
        }
        let need = packed_len(len, bits);
        if bytes.len() != need {
            return Err(Error::Config(format!(
                "codebuf length mismatch: {} bytes for {len} codes at {bits} bits (need {need})"
            )));
        }
        if bits == 1 {
            check_plane_padding(&bytes, len, "sign")?;
            return Ok(CodeBuf::Bit1(bytes, len));
        }
        // i32 rail math: -(1i8 << 7) would overflow at bits 8.
        let lo = -(1i32 << (bits - 1));
        let hi = (1i32 << (bits - 1)) - 1;
        let buf = if bits <= 2 {
            // every 2-bit pattern is a valid code; only pads can be bad
            CodeBuf::Crumb2(bytes, len)
        } else if bits <= 4 {
            if bits < 4 {
                for (k, &byte) in bytes.iter().enumerate() {
                    for (j, c) in [nib4_lo(byte) as i32, nib4_hi(byte) as i32].into_iter().enumerate()
                    {
                        let idx = 2 * k + j;
                        if idx < len && !(lo..=hi).contains(&c) {
                            return Err(Error::Config(format!(
                                "codebuf code {c} at index {idx} outside the {bits}-bit rail [{lo}, {hi}]"
                            )));
                        }
                    }
                }
            }
            CodeBuf::Nib4(bytes, len)
        } else {
            let codes: Vec<i8> = bytes.into_iter().map(|b| b as i8).collect();
            if bits < 8 {
                for (idx, &c) in codes.iter().enumerate() {
                    let c = c as i32;
                    if !(lo..=hi).contains(&c) {
                        return Err(Error::Config(format!(
                            "codebuf code {c} at index {idx} outside the {bits}-bit rail [{lo}, {hi}]"
                        )));
                    }
                }
            }
            CodeBuf::I8(codes)
        };
        // Padding positions of a partial tail byte must be zero: the
        // packers emit exactly that, so anything else is corruption that
        // would otherwise round-trip silently.
        match &buf {
            CodeBuf::Nib4(v, n) if n % 2 != 0 => {
                if nib4_hi(v[n / 2]) != 0 {
                    return Err(Error::Config("codebuf tail padding nibble is non-zero".into()));
                }
            }
            CodeBuf::Crumb2(v, n) if n % 4 != 0 => {
                for j in (n % 4)..4 {
                    if crumb2(v[n / 4], j) != 0 {
                        return Err(Error::Config("codebuf tail padding crumb is non-zero".into()));
                    }
                }
            }
            _ => {}
        }
        Ok(buf)
    }

    /// Deserialize for a quantized precision — the validated
    /// precision-keyed twin of [`CodeBuf::from_packed`]. Ternary wire
    /// bytes are the mask plane followed by the sign plane; besides the
    /// length and padding rules this enforces the canonical-encoding
    /// invariant that no sign bit is set where the mask bit is clear
    /// (such a weight would silently decode as 0, so the corruption
    /// must be typed instead of round-tripping).
    pub fn from_packed_for(bytes: Vec<u8>, len: usize, precision: Precision) -> Result<CodeBuf> {
        let Precision::Ternary = precision else {
            return CodeBuf::from_packed(bytes, len, precision.bits());
        };
        let pl = plane_len(len);
        if bytes.len() != 2 * pl {
            return Err(Error::Config(format!(
                "codebuf length mismatch: {} bytes for {len} ternary codes (need {})",
                bytes.len(),
                2 * pl
            )));
        }
        check_plane_padding(&bytes[..pl], len, "mask")?;
        check_plane_padding(&bytes[pl..], len, "sign")?;
        for k in 0..pl {
            if bytes[pl + k] & !bytes[k] != 0 {
                return Err(Error::Config(format!(
                    "ternary codebuf sign bit set outside the nonzero mask in plane byte {k}"
                )));
            }
        }
        Ok(CodeBuf::Tern(bytes, len))
    }

    /// The raw packed bytes, as [`CodeBuf::from_packed`] accepts them
    /// (i8 codes reinterpreted as bytes on the one-per-byte layout) —
    /// the snapshot artifact's wire form for a weight section.
    pub fn to_packed_bytes(&self) -> Vec<u8> {
        match self {
            CodeBuf::I8(v) => v.iter().map(|&c| c as u8).collect(),
            CodeBuf::Nib4(v, _) | CodeBuf::Crumb2(v, _) | CodeBuf::Bit1(v, _) | CodeBuf::Tern(v, _) => {
                v.clone()
            }
        }
    }

    /// Logical element count.
    pub fn len(&self) -> usize {
        match self {
            CodeBuf::I8(v) => v.len(),
            CodeBuf::Nib4(_, n)
            | CodeBuf::Crumb2(_, n)
            | CodeBuf::Bit1(_, n)
            | CodeBuf::Tern(_, n) => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage bytes (the weight-traffic column of the Fig-6 study).
    pub fn bytes(&self) -> usize {
        match self {
            CodeBuf::I8(v) => v.len(),
            CodeBuf::Nib4(v, _) | CodeBuf::Crumb2(v, _) | CodeBuf::Bit1(v, _) | CodeBuf::Tern(v, _) => {
                v.len()
            }
        }
    }

    /// One code, sign-extended.
    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        match self {
            CodeBuf::I8(v) => v[i],
            CodeBuf::Nib4(v, _) => {
                let byte = v[i / 2];
                if i % 2 == 0 {
                    nib4_lo(byte)
                } else {
                    nib4_hi(byte)
                }
            }
            CodeBuf::Crumb2(v, _) => crumb2(v[i / 4], i % 4),
            CodeBuf::Bit1(v, _) => bit1_get(v, i),
            CodeBuf::Tern(v, n) => tern_get(&v[..plane_len(*n)], &v[plane_len(*n)..], i),
        }
    }

    /// Borrow the sign plane of a binary buffer (the bitplane prepack's
    /// input; `None` for every other layout).
    pub fn bit1_plane(&self) -> Option<&[u8]> {
        match self {
            CodeBuf::Bit1(v, _) => Some(v),
            _ => None,
        }
    }

    /// Borrow the (mask, sign) planes of a ternary buffer.
    pub fn tern_planes(&self) -> Option<(&[u8], &[u8])> {
        match self {
            CodeBuf::Tern(v, n) => Some(v.split_at(plane_len(*n))),
            _ => None,
        }
    }

    /// All codes, unpacked (test/inspection convenience; the kernels use
    /// [`CodeBuf::slice_into`] / direct slices instead).
    pub fn to_vec(&self) -> Vec<i8> {
        match self {
            CodeBuf::I8(v) => v.clone(),
            CodeBuf::Nib4(v, n) => {
                let mut out = vec![0i8; *n];
                unpack_block_nib4(v, *n, &mut out);
                out
            }
            CodeBuf::Crumb2(v, n) => {
                let mut out = vec![0i8; *n];
                unpack_block_crumb2(v, *n, &mut out);
                out
            }
            CodeBuf::Bit1(..) | CodeBuf::Tern(..) => (0..self.len()).map(|i| self.get(i)).collect(),
        }
    }

    /// Unpack the element range `[start, start + out.len())` into `out`
    /// (the per-panel unpack step of the row-major packed GEMM).
    #[inline]
    pub fn slice_into(&self, start: usize, out: &mut [i8]) {
        match self {
            CodeBuf::I8(v) => out.copy_from_slice(&v[start..start + out.len()]),
            CodeBuf::Nib4(v, _) => unpack_nib4_into(v, start, out),
            CodeBuf::Crumb2(v, _) => unpack_crumb2_into(v, start, out),
            CodeBuf::Bit1(..) | CodeBuf::Tern(..) => {
                for (j, o) in out.iter_mut().enumerate() {
                    *o = self.get(start + j);
                }
            }
        }
    }

    /// Borrow the range directly when stored one-code-per-byte (lets the
    /// GEMM skip the unpack copy on the i8 path).
    #[inline]
    pub fn as_i8_slice(&self, start: usize, len: usize) -> Option<&[i8]> {
        match self {
            CodeBuf::I8(v) => Some(&v[start..start + len]),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nib4_roundtrip_all_256_byte_patterns() {
        // Every byte decodes to two codes in [-8, 7] and re-encodes to
        // exactly itself: the codec is a bijection on the packed domain.
        for byte in 0u8..=255 {
            let (lo, hi) = (nib4_lo(byte), nib4_hi(byte));
            assert!((-8..=7).contains(&lo) && (-8..=7).contains(&hi), "byte {byte:#04x}");
            let repacked = pack_nib4(&[lo, hi]);
            assert_eq!(repacked, vec![byte], "byte {byte:#04x} -> ({lo}, {hi})");
        }
    }

    #[test]
    fn nib4_roundtrip_all_code_values() {
        // And the other direction: every representable code survives a
        // pack/unpack round trip in both nibble positions.
        for a in -8i8..=7 {
            for b in -8i8..=7 {
                let packed = pack_nib4(&[a, b]);
                let mut out = [0i8; 2];
                unpack_nib4_into(&packed, 0, &mut out);
                assert_eq!(out, [a, b]);
            }
        }
    }

    #[test]
    fn odd_lengths_and_offsets_roundtrip() {
        // Odd-length rows (the final high nibble is padding) and odd
        // start offsets (rows of an odd-width matrix begin mid-byte).
        let codes: Vec<i8> = (0..31).map(|i| ((i * 5) % 16) as i8 - 8).collect();
        let packed = pack_nib4(&codes);
        assert_eq!(packed.len(), 16, "31 codes -> 16 bytes");
        for start in 0..codes.len() {
            for len in 0..=(codes.len() - start).min(9) {
                let mut out = vec![0i8; len];
                unpack_nib4_into(&packed, start, &mut out);
                assert_eq!(out, &codes[start..start + len], "start {start} len {len}");
            }
        }
    }

    #[test]
    fn codebuf_dispatch_matches_layout() {
        let codes: Vec<i8> = vec![-8, -1, 0, 3, 7];
        let nib = CodeBuf::from_codes(&codes, 4);
        let i8s = CodeBuf::from_codes(&codes, 8);
        assert_eq!(nib.len(), 5);
        assert_eq!(nib.bytes(), 3, "5 codes pack into 3 bytes");
        assert_eq!(i8s.bytes(), 5);
        assert_eq!(nib.to_vec(), codes);
        assert_eq!(i8s.to_vec(), codes);
        for i in 0..codes.len() {
            assert_eq!(nib.get(i), codes[i]);
            assert_eq!(i8s.get(i), codes[i]);
        }
        let mut out = [0i8; 3];
        nib.slice_into(1, &mut out);
        assert_eq!(out, [-1, 0, 3]);
        assert!(nib.as_i8_slice(0, 2).is_none());
        assert_eq!(i8s.as_i8_slice(1, 3), Some(&codes[1..4]));
    }

    #[test]
    fn bits_2_packs_four_per_byte_and_3_rides_the_nibble_codec() {
        // int2 now has its own four-per-byte codec (quartering weight
        // traffic); int3 codes still pack two-per-byte as nibbles.
        let codes: Vec<i8> = vec![-2, -1, 0, 1, -2, 1, 0];
        let buf = CodeBuf::from_codes(&codes, 2);
        assert!(matches!(buf, CodeBuf::Crumb2(..)));
        assert_eq!(buf.bytes(), 2, "7 codes pack into 2 bytes");
        assert_eq!(buf.to_vec(), codes);
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(buf.get(i), c, "idx {i}");
        }
        assert!(buf.as_i8_slice(0, 2).is_none());
        let b3 = CodeBuf::from_codes(&codes, 3);
        assert!(matches!(b3, CodeBuf::Nib4(..)));
        assert_eq!(b3.to_vec(), codes);
    }

    #[test]
    fn crumb2_roundtrip_all_256_byte_patterns() {
        // Every byte decodes to four codes in [-2, 1] and re-encodes to
        // exactly itself: the int2 codec is a bijection on bytes.
        for byte in 0u8..=255 {
            let codes: Vec<i8> = (0..4).map(|j| crumb2(byte, j)).collect();
            assert!(codes.iter().all(|c| (-2..=1).contains(c)), "byte {byte:#04x}");
            assert_eq!(pack_crumb2(&codes), vec![byte], "byte {byte:#04x} -> {codes:?}");
        }
    }

    #[test]
    fn crumb2_odd_lengths_and_offsets_roundtrip() {
        // Lengths that leave 1..=3 padding crumbs and starts at every
        // crumb position (rows of an odd-width matrix begin mid-byte).
        let codes: Vec<i8> = (0..37).map(|i| ((i * 3) % 4) as i8 - 2).collect();
        let packed = pack_crumb2(&codes);
        assert_eq!(packed.len(), 10, "37 codes -> 10 bytes");
        for start in 0..codes.len() {
            for len in 0..=(codes.len() - start).min(11) {
                let mut out = vec![0i8; len];
                unpack_crumb2_into(&packed, start, &mut out);
                assert_eq!(out, &codes[start..start + len], "start {start} len {len}");
            }
        }
    }

    #[test]
    fn from_packed_roundtrips_every_width() {
        // to_packed_bytes -> from_packed is the identity at every
        // engine width, including odd lengths with padded tail bytes.
        for bits in 2u32..=8 {
            let lo = -(1i32 << (bits - 1));
            let levels = 1i32 << bits;
            let codes: Vec<i8> = (0..37).map(|i| (lo + (i * 5) % levels) as i8).collect();
            let buf = CodeBuf::from_codes(&codes, bits);
            let bytes = buf.to_packed_bytes();
            assert_eq!(bytes.len(), packed_len(codes.len(), bits), "bits {bits}");
            let back = CodeBuf::from_packed(bytes, codes.len(), bits).unwrap();
            assert_eq!(back, buf, "bits {bits}");
            assert_eq!(back.to_vec(), codes, "bits {bits}");
        }
    }

    #[test]
    fn from_packed_rejects_length_bits_mismatches_as_config_errors() {
        // The latent bug class: a short (or long) buffer must be a typed
        // Error::Config at deserialization time, not an index panic deep
        // inside PanelStore packing later.
        let codes: Vec<i8> = vec![-2, -1, 0, 1, -2, 1, 0];
        for bits in [2u32, 4, 8] {
            let good = CodeBuf::from_codes(&codes, bits).to_packed_bytes();
            let mut short = good.clone();
            short.pop();
            let err = CodeBuf::from_packed(short, codes.len(), bits).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "bits {bits} short: {err}");
            let mut long = good.clone();
            long.push(0);
            let err = CodeBuf::from_packed(long, codes.len(), bits).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "bits {bits} long: {err}");
            // declared length inconsistent with the byte count
            let err = CodeBuf::from_packed(good, codes.len() + 9, bits).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "bits {bits} bad len: {err}");
        }
        // bits outside the engine range
        let err = CodeBuf::from_packed(vec![0, 0], 2, 9).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        let err = CodeBuf::from_packed(vec![0], 4, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }

    #[test]
    fn from_packed_rejects_off_rail_codes_and_dirty_padding() {
        // bits 3 stored as nibbles: 7 encodes fine as a nibble but sits
        // outside the 3-bit rail [-4, 3].
        let bad3 = pack_nib4(&[7, 0]);
        let err = CodeBuf::from_packed(bad3, 2, 3).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // bits 5 stored as bytes: 100 is a valid i8 but off the rail.
        let err = CodeBuf::from_packed(vec![100], 1, 5).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // odd-length nibble stream with a non-zero padding nibble: the
        // packers always emit zero there, so this is corruption.
        let mut dirty = pack_nib4(&[1, 2, 3]);
        dirty[1] |= 0xF0;
        let err = CodeBuf::from_packed(dirty, 3, 4).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // same for a partial crumb byte
        let mut dirty2 = pack_crumb2(&[1, -1, 0, 1, 1]);
        dirty2[1] |= 0b1100;
        let err = CodeBuf::from_packed(dirty2, 5, 2).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // while canonical encodings pass
        assert!(CodeBuf::from_packed(pack_nib4(&[1, 2, 3]), 3, 4).is_ok());
        assert!(CodeBuf::from_packed(pack_crumb2(&[1, -1, 0, 1, 1]), 5, 2).is_ok());
    }

    #[test]
    fn swar_nib4_matches_scalar_for_all_256_byte_patterns() {
        // Each byte value in every lane of the u64, against the scalar
        // sign-extension: SWAR lane arithmetic must never leak across
        // byte boundaries.
        let mut out = [0i8; 16];
        for byte in 0u8..=255 {
            for lane in 0..8 {
                let mut bytes = [0x5Au8; 8];
                bytes[lane] = byte;
                unpack16_nib4(u64::from_le_bytes(bytes), &mut out);
                for k in 0..16 {
                    let want = if k % 2 == 0 { nib4_lo(bytes[k / 2]) } else { nib4_hi(bytes[k / 2]) };
                    assert_eq!(out[k], want, "byte {byte:#04x} lane {lane} elem {k}");
                }
            }
        }
    }

    #[test]
    fn swar_crumb2_matches_scalar_for_all_256_byte_patterns() {
        let mut out = [0i8; 32];
        for byte in 0u8..=255 {
            for lane in 0..8 {
                let mut bytes = [0x6Cu8; 8];
                bytes[lane] = byte;
                unpack32_crumb2(u64::from_le_bytes(bytes), &mut out);
                for k in 0..32 {
                    assert_eq!(
                        out[k],
                        crumb2(bytes[k / 4], k % 4),
                        "byte {byte:#04x} lane {lane} elem {k}"
                    );
                }
            }
        }
    }

    #[test]
    fn bit1_roundtrip_all_256_byte_patterns() {
        // Every plane byte decodes to eight codes in {-1,+1} and
        // re-encodes to exactly itself: the sign-bitplane codec is a
        // bijection on bytes.
        for byte in 0u8..=255 {
            let plane = [byte];
            let codes: Vec<i8> = (0..8).map(|i| bit1_get(&plane, i)).collect();
            assert!(codes.iter().all(|&c| c == -1 || c == 1), "byte {byte:#04x}");
            assert_eq!(pack_bit1(&codes), vec![byte], "byte {byte:#04x} -> {codes:?}");
        }
    }

    #[test]
    fn tern_roundtrip_all_256_mask_patterns() {
        // For every mask byte, with the sign plane all-negative (sign =
        // mask) and all-positive (sign = 0): eight codes in {-1,0,+1},
        // and the canonical pack reproduces both planes bit-for-bit.
        for mask in 0u8..=255 {
            for sign in [0u8, mask] {
                let codes: Vec<i8> = (0..8).map(|i| tern_get(&[mask], &[sign], i)).collect();
                assert!(codes.iter().all(|c| (-1..=1).contains(c)), "mask {mask:#04x}");
                assert_eq!(pack_tern(&codes), vec![mask, sign], "mask {mask:#04x} sign {sign:#04x}");
            }
        }
    }

    #[test]
    fn bitplane_codebuf_roundtrips_odd_lengths() {
        // 13 codes leave 3 pad bits per plane; get/to_vec/slice_into and
        // the packed-bytes round trip must all agree.
        let b1: Vec<i8> = (0..13).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
        let buf = CodeBuf::from_codes(&b1, 1);
        assert!(matches!(buf, CodeBuf::Bit1(..)));
        assert_eq!(buf.len(), 13);
        assert_eq!(buf.bytes(), 2, "13 sign bits pack into 2 bytes");
        assert_eq!(buf.to_vec(), b1);
        let mut out = [0i8; 5];
        buf.slice_into(4, &mut out);
        assert_eq!(&out[..], &b1[4..9]);
        assert!(buf.as_i8_slice(0, 4).is_none());
        let back = CodeBuf::from_packed(buf.to_packed_bytes(), 13, 1).unwrap();
        assert_eq!(back, buf);

        let t: Vec<i8> = (0..13).map(|i| (i % 3) as i8 - 1).collect();
        let tbuf = CodeBuf::from_codes_for(&t, Precision::Ternary);
        assert!(matches!(tbuf, CodeBuf::Tern(..)));
        assert_eq!(tbuf.bytes(), 4, "two 2-byte planes");
        assert_eq!(tbuf.to_vec(), t);
        for (i, &c) in t.iter().enumerate() {
            assert_eq!(tbuf.get(i), c, "idx {i}");
        }
        let (mask, sign) = tbuf.tern_planes().unwrap();
        assert_eq!((mask.len(), sign.len()), (2, 2));
        let tback =
            CodeBuf::from_packed_for(tbuf.to_packed_bytes(), 13, Precision::Ternary).unwrap();
        assert_eq!(tback, tbuf);
        // from_codes_for routes non-ternary precisions to the width codecs
        assert!(matches!(CodeBuf::from_codes_for(&b1, Precision::Int(1)), CodeBuf::Bit1(..)));
    }

    #[test]
    fn bitplane_from_packed_rejects_corruption_as_config_errors() {
        let b1: Vec<i8> = (0..11).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let good = CodeBuf::from_codes(&b1, 1).to_packed_bytes();
        let err = CodeBuf::from_packed(good[..1].to_vec(), 11, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "short: {err}");
        let mut long = good.clone();
        long.push(0);
        let err = CodeBuf::from_packed(long, 11, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "long: {err}");
        let mut dirty = good.clone();
        dirty[1] |= 0x80; // pad bit 15 of an 11-code plane
        let err = CodeBuf::from_packed(dirty, 11, 1).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "dirty pad: {err}");
        assert!(CodeBuf::from_packed(good, 11, 1).is_ok());
        let err = CodeBuf::from_packed(vec![0], 8, 0).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "bits 0: {err}");

        let t: Vec<i8> = vec![1, 0, -1, 0, 1, -1, 0, 0, 1, -1, 0];
        let tgood = CodeBuf::from_codes_for(&t, Precision::Ternary).to_packed_bytes();
        assert_eq!(tgood.len(), 4);
        let err = CodeBuf::from_packed_for(tgood[..3].to_vec(), 11, Precision::Ternary).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "tern short: {err}");
        // sign bit set where the mask bit is clear (index 1 is a zero)
        let mut noncanon = tgood.clone();
        noncanon[2] |= 0b10;
        let err = CodeBuf::from_packed_for(noncanon, 11, Precision::Ternary).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "tern non-canonical: {err}");
        // dirty pad in the mask plane
        let mut tdirty = tgood.clone();
        tdirty[1] |= 0x80;
        let err = CodeBuf::from_packed_for(tdirty, 11, Precision::Ternary).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "tern dirty pad: {err}");
        assert!(CodeBuf::from_packed_for(tgood, 11, Precision::Ternary).is_ok());
    }

    #[test]
    fn binarize_and_ternarize_semantics() {
        let (codes, alpha) = binarize(&[0.5, -1.5, 0.0, -2.0]);
        assert_eq!(codes, vec![1, -1, 1, -1], "sign(0) = +1");
        assert!((alpha - 1.0).abs() < 1e-6, "alpha = mean |w| = {alpha}");
        let (zc, za) = binarize(&[0.0; 7]);
        assert_eq!(zc, vec![1; 7]);
        assert_eq!(za, 0.0, "all-zero tensor dequantizes to exact zeros");

        // mean |w| = 1.0, thr = 0.7: only the +/-2.0 and -1.0 survive
        let (t, ta) = ternarize(&[2.0, -0.5, 0.0, -1.0, 0.5, -2.0]);
        assert_eq!(t, vec![1, 0, 0, -1, 0, -1]);
        assert!((ta - (5.0 / 3.0)).abs() < 1e-6, "alpha over nonzero support = {ta}");
        let (tz, tza) = ternarize(&[0.0; 5]);
        assert_eq!(tz, vec![0; 5]);
        assert_eq!(tza, 0.0);
    }

    #[test]
    fn packed_len_for_matches_wire_sizes() {
        for n in [0usize, 1, 7, 8, 9, 64, 65, 127] {
            assert_eq!(packed_len_for(n, Precision::Int(1)), n.div_ceil(8), "n {n}");
            assert_eq!(packed_len_for(n, Precision::Ternary), 2 * n.div_ceil(8), "n {n}");
            assert_eq!(packed_len_for(n, Precision::Int(2)), n.div_ceil(4), "n {n}");
            assert_eq!(packed_len_for(n, Precision::Int(8)), n, "n {n}");
        }
    }

    #[test]
    fn swar_block_unpack_matches_scalar_at_every_offset_and_length() {
        // The bulk unpackers over a varied stream: every byte-aligned
        // start offset x every length (covering full-word bodies and
        // 1..=15 / 1..=31 element tails) equals the scalar path.
        let packed: Vec<u8> = (0..64u32).map(|i| (i.wrapping_mul(73) ^ 0xA7) as u8).collect();
        for start_byte in 0..32 {
            let window = &packed[start_byte..];
            for n in 0..=48usize {
                let mut swar = vec![0i8; n];
                unpack_block_nib4(window, n, &mut swar);
                let mut scalar = vec![0i8; n];
                unpack_nib4_into(window, 0, &mut scalar);
                assert_eq!(swar, scalar, "nib4 start {start_byte} n {n}");

                let mut swar = vec![0i8; n];
                unpack_block_crumb2(window, n, &mut swar);
                let mut scalar = vec![0i8; n];
                unpack_crumb2_into(window, 0, &mut scalar);
                assert_eq!(swar, scalar, "crumb2 start {start_byte} n {n}");
            }
        }
    }
}

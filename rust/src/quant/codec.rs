//! Code storage for the quantized deployment engines: one codec per
//! storage class, behind a single enum so the engines are generic over
//! bitwidth.
//!
//! * bits 5..=8 — one centered i8 code per byte (the PR-3 layout).
//! * bits 2..=4 — two centered codes per byte, 4-bit two's complement:
//!   element `2k` in the low nibble, `2k+1` in the high nibble. This is
//!   the packing that halves weight traffic again below int8 — the
//!   memory-bandwidth lever behind the sub-8-bit deployment study.
//!
//! The codes themselves come from [`crate::quant::QParams::quantize_code`]
//! (centered on the zero point, saturating at the signed rails), so
//! every consumer — scalar GEMV, packed GEMM, broadcast — shares one
//! quantization rule. Pack/unpack is lossless for every representable
//! code (pinned by the exhaustive tests below and the property suite in
//! `rust/tests/engine_parity.rs`).

/// Sign-extend the low nibble of a packed byte to an i8 code.
#[inline]
pub fn nib4_lo(byte: u8) -> i8 {
    ((byte as i8) << 4) >> 4
}

/// Sign-extend the high nibble of a packed byte to an i8 code.
#[inline]
pub fn nib4_hi(byte: u8) -> i8 {
    (byte as i8) >> 4
}

/// Pack centered codes (each in [-8, 7]) two per byte; an odd tail
/// leaves the final high nibble zero.
pub fn pack_nib4(codes: &[i8]) -> Vec<u8> {
    debug_assert!(codes.iter().all(|&c| (-8..=7).contains(&c)), "nib4 code out of range");
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    for (i, &c) in codes.iter().enumerate() {
        let nib = (c as u8) & 0x0F;
        if i % 2 == 0 {
            out[i / 2] |= nib;
        } else {
            out[i / 2] |= nib << 4;
        }
    }
    out
}

/// Unpack `out.len()` consecutive codes starting at element offset
/// `start` (which may be odd — sub-byte rows need not be byte-aligned).
#[inline]
pub fn unpack_nib4_into(packed: &[u8], start: usize, out: &mut [i8]) {
    for (j, o) in out.iter_mut().enumerate() {
        let idx = start + j;
        let byte = packed[idx / 2];
        *o = if idx % 2 == 0 { nib4_lo(byte) } else { nib4_hi(byte) };
    }
}

/// Storage for one tensor's centered integer codes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodeBuf {
    /// One code per byte (bits 5..=8).
    I8(Vec<i8>),
    /// Two 4-bit two's-complement codes per byte (bits 2..=4); the
    /// second field is the logical element count.
    Nib4(Vec<u8>, usize),
}

impl CodeBuf {
    /// Pack `codes` for a `bits`-wide grid (codes must already be
    /// centered and clipped to the signed range for `bits`).
    pub fn from_codes(codes: &[i8], bits: u32) -> CodeBuf {
        if bits <= 4 {
            CodeBuf::Nib4(pack_nib4(codes), codes.len())
        } else {
            CodeBuf::I8(codes.to_vec())
        }
    }

    /// Logical element count.
    pub fn len(&self) -> usize {
        match self {
            CodeBuf::I8(v) => v.len(),
            CodeBuf::Nib4(_, n) => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage bytes (the weight-traffic column of the Fig-6 study).
    pub fn bytes(&self) -> usize {
        match self {
            CodeBuf::I8(v) => v.len(),
            CodeBuf::Nib4(v, _) => v.len(),
        }
    }

    /// One code, sign-extended.
    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        match self {
            CodeBuf::I8(v) => v[i],
            CodeBuf::Nib4(v, _) => {
                let byte = v[i / 2];
                if i % 2 == 0 {
                    nib4_lo(byte)
                } else {
                    nib4_hi(byte)
                }
            }
        }
    }

    /// All codes, unpacked (test/inspection convenience; the kernels use
    /// [`CodeBuf::slice_into`] / direct slices instead).
    pub fn to_vec(&self) -> Vec<i8> {
        match self {
            CodeBuf::I8(v) => v.clone(),
            CodeBuf::Nib4(v, n) => {
                let mut out = vec![0i8; *n];
                unpack_nib4_into(v, 0, &mut out);
                out
            }
        }
    }

    /// Unpack the element range `[start, start + out.len())` into `out`
    /// (the per-panel unpack step of the packed GEMM).
    #[inline]
    pub fn slice_into(&self, start: usize, out: &mut [i8]) {
        match self {
            CodeBuf::I8(v) => out.copy_from_slice(&v[start..start + out.len()]),
            CodeBuf::Nib4(v, _) => unpack_nib4_into(v, start, out),
        }
    }

    /// Borrow the range directly when stored one-code-per-byte (lets the
    /// GEMM skip the unpack copy on the i8 path).
    #[inline]
    pub fn as_i8_slice(&self, start: usize, len: usize) -> Option<&[i8]> {
        match self {
            CodeBuf::I8(v) => Some(&v[start..start + len]),
            CodeBuf::Nib4(..) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nib4_roundtrip_all_256_byte_patterns() {
        // Every byte decodes to two codes in [-8, 7] and re-encodes to
        // exactly itself: the codec is a bijection on the packed domain.
        for byte in 0u8..=255 {
            let (lo, hi) = (nib4_lo(byte), nib4_hi(byte));
            assert!((-8..=7).contains(&lo) && (-8..=7).contains(&hi), "byte {byte:#04x}");
            let repacked = pack_nib4(&[lo, hi]);
            assert_eq!(repacked, vec![byte], "byte {byte:#04x} -> ({lo}, {hi})");
        }
    }

    #[test]
    fn nib4_roundtrip_all_code_values() {
        // And the other direction: every representable code survives a
        // pack/unpack round trip in both nibble positions.
        for a in -8i8..=7 {
            for b in -8i8..=7 {
                let packed = pack_nib4(&[a, b]);
                let mut out = [0i8; 2];
                unpack_nib4_into(&packed, 0, &mut out);
                assert_eq!(out, [a, b]);
            }
        }
    }

    #[test]
    fn odd_lengths_and_offsets_roundtrip() {
        // Odd-length rows (the final high nibble is padding) and odd
        // start offsets (rows of an odd-width matrix begin mid-byte).
        let codes: Vec<i8> = (0..31).map(|i| ((i * 5) % 16) as i8 - 8).collect();
        let packed = pack_nib4(&codes);
        assert_eq!(packed.len(), 16, "31 codes -> 16 bytes");
        for start in 0..codes.len() {
            for len in 0..=(codes.len() - start).min(9) {
                let mut out = vec![0i8; len];
                unpack_nib4_into(&packed, start, &mut out);
                assert_eq!(out, &codes[start..start + len], "start {start} len {len}");
            }
        }
    }

    #[test]
    fn codebuf_dispatch_matches_layout() {
        let codes: Vec<i8> = vec![-8, -1, 0, 3, 7];
        let nib = CodeBuf::from_codes(&codes, 4);
        let i8s = CodeBuf::from_codes(&codes, 8);
        assert_eq!(nib.len(), 5);
        assert_eq!(nib.bytes(), 3, "5 codes pack into 3 bytes");
        assert_eq!(i8s.bytes(), 5);
        assert_eq!(nib.to_vec(), codes);
        assert_eq!(i8s.to_vec(), codes);
        for i in 0..codes.len() {
            assert_eq!(nib.get(i), codes[i]);
            assert_eq!(i8s.get(i), codes[i]);
        }
        let mut out = [0i8; 3];
        nib.slice_into(1, &mut out);
        assert_eq!(out, [-1, 0, 3]);
        assert!(nib.as_i8_slice(0, 2).is_none());
        assert_eq!(i8s.as_i8_slice(1, 3), Some(&codes[1..4]));
    }

    #[test]
    fn bits_2_and_3_ride_the_nibble_codec() {
        // int2/int3 codes fit the nibble range; they pack two-per-byte
        // today (a four-per-byte int2 codec is a ROADMAP follow-on).
        let codes: Vec<i8> = vec![-2, -1, 0, 1, -2, 1, 0];
        let buf = CodeBuf::from_codes(&codes, 2);
        assert!(matches!(buf, CodeBuf::Nib4(..)));
        assert_eq!(buf.to_vec(), codes);
    }
}

//! Software IEEE-754 half-precision rounding — the paper's fp16
//! post-training quantization (§3.1): map each f32 to the nearest
//! representable f16 (round-to-nearest-even) and back.
//!
//! No `half` crate offline, so the conversion is implemented directly;
//! tests pin it against known bit patterns and the paper's format
//! (1 sign, 5 exponent, 10 fraction bits).

/// f32 -> f16 bit pattern with round-to-nearest-even (IEEE default).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        return sign | 0x7c00 | if frac != 0 { 0x0200 } else { 0 };
    }

    // Unbiased exponent, rebased for f16 (bias 15).
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal or zero.
        if e < -10 {
            return sign; // rounds to zero
        }
        // Add the implicit leading 1, shift into subnormal position.
        let mant = frac | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half_ulp = 1u32 << (shift - 1);
        let rounded = mant + half_ulp - 1 + ((mant >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }

    // Normal: keep 10 fraction bits, round-to-nearest-even on bit 13.
    let mant = frac >> 13;
    let rest = frac & 0x1fff;
    let mut h = sign | ((e as u16) << 10) | mant as u16;
    if rest > 0x1000 || (rest == 0x1000 && (mant & 1) == 1) {
        h = h.wrapping_add(1); // may carry into exponent; that is correct
    }
    h
}

/// f16 bit pattern -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13) // inf/nan
    } else if exp == 0 {
        if frac == 0 {
            sign // zero
        } else {
            // subnormal: normalize. frac * 2^-24 with leading bit at
            // position (10 - k) => biased exponent 113 - k.
            let mut e: i32 = 113;
            let mut f = frac;
            while f & 0x0400 == 0 {
                f <<= 1;
                e -= 1;
            }
            f &= 0x03ff;
            sign | ((e as u32) << 23) | (f << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip a value through f16 (the PTQ-fp16 operation).
#[inline]
pub fn fp16_roundtrip(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// fp16 PTQ over a slice in place.
pub fn fp16_quant_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = fp16_roundtrip(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // max finite half
        assert_eq!(f32_to_f16_bits(65536.0), 0x7c00); // overflow -> inf
        assert_eq!(f32_to_f16_bits(5.9604645e-8), 0x0001); // smallest subnormal
    }

    #[test]
    fn round_trip_exact_for_representable() {
        for x in [0.0f32, 1.0, -1.5, 0.25, 2048.0, -0.0009765625] {
            assert_eq!(fp16_roundtrip(x), x, "{x} should be exactly representable");
        }
    }

    #[test]
    fn relative_error_within_half_ulp() {
        // 10 fraction bits => relative error <= 2^-11 for normals.
        let mut x = 1e-3f32;
        while x < 1e4 {
            let r = fp16_roundtrip(x);
            let rel = ((r - x) / x).abs();
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "x={x} r={r} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10: ties to even (1.0).
        let tie = 1.0 + 1.0 / 2048.0;
        assert_eq!(fp16_roundtrip(tie), 1.0);
        // slightly above the tie rounds up
        let above = 1.0 + 1.3 / 2048.0;
        assert_eq!(fp16_roundtrip(above), 1.0 + 1.0 / 1024.0);
    }

    #[test]
    fn inf_nan_preserved() {
        assert_eq!(fp16_roundtrip(f32::INFINITY), f32::INFINITY);
        assert_eq!(fp16_roundtrip(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(fp16_roundtrip(f32::NAN).is_nan());
    }

    #[test]
    fn all_f16_values_round_trip_bits() {
        // Every finite half value must survive f16 -> f32 -> f16 exactly.
        for h in 0u16..=0xffff {
            let exp = (h >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan patterns: payload not preserved bit-exactly
            }
            let x = f16_bits_to_f32(h);
            let h2 = f32_to_f16_bits(x);
            assert_eq!(h, h2, "bits 0x{h:04x} -> {x} -> 0x{h2:04x}");
        }
    }
}

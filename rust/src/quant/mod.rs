//! Quantization engine (Layer-3 side).
//!
//! * [`affine`] — uniform affine quantizer, bit-exact with the Python
//!   oracle (paper §3.1).
//! * [`precision`] — the [`Precision`] selector the whole deployment
//!   stack (engines, ActorQ broadcast, `--bits` sweeps) shares.
//! * [`codec`] — centered-code storage: one i8 code per byte, two
//!   packed 4-bit codes per byte at 3..=4 bits, four packed 2-bit
//!   codes per byte at int2, and sign/mask bitplanes at int1/ternary —
//!   plus SWAR bulk unpackers (16/32 codes per `u64` load) for the
//!   panel-major kernels and the XNOR-popcount weight quantizers
//!   ([`codec::binarize`] / [`codec::ternarize`]).
//! * [`fp16`] — software IEEE-754 half rounding (PTQ-fp16).
//! * [`ptq`] — post-training quantization over parameter sets
//!   (paper Algorithm 1).
//! * [`stats`] — weight-distribution analysis (Figures 3/4, Table 3).

pub mod affine;
pub mod codec;
pub mod fp16;
pub mod precision;
pub mod ptq;
pub mod stats;

pub use affine::{fake_quant_per_axis, fake_quant_slice, fake_quant_slice_with_range, QParams};
pub use codec::{binarize, ternarize, CodeBuf};
pub use fp16::{fp16_quant_slice, fp16_roundtrip};
pub use precision::Precision;
pub use ptq::{quantize_params, relative_error_pct, PtqMethod};
pub use stats::{render_histogram, weight_stats, WeightStats};

//! Property tests for the ActorQ broadcast path (hand-rolled threads —
//! no loom offline): quantize-on-broadcast round-trip error stays on the
//! quantizer grid's bound, and parameter versions observed by readers
//! are monotone non-decreasing under concurrent publishers.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use quarl::actorq::{ActorEngine, ParamBroadcast, Precision};
use quarl::rng::Pcg32;
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

// ------------------------------------------------------- quantize-on-broadcast

#[test]
fn prop_broadcast_roundtrip_error_bounded() {
    // ParamSet -> i8 codes -> dequant: per-weight error is bounded by one
    // grid step (the floor-based TFLite quantizer's bound) for every code
    // off the saturation rails, and the mean error sits near the half-step
    // a uniform quantizer promises on average.
    let mut rng = Pcg32::new(401, 1);
    for case in 0..30u64 {
        let hidden = 8 + rng.below_usize(56);
        let p = mlp_params(&[4, hidden, 2], 500 + case);
        let bc = ParamBroadcast::new(&p, Precision::Int(8)).unwrap();
        let snap = bc.latest();
        let ActorEngine::Quant(ref eng) = snap.engine else {
            panic!("int8 precision must publish the quantized engine");
        };
        assert_eq!(eng.precision(), Precision::Int(8));
        for (li, layer) in eng.layers.iter().enumerate() {
            let w = &p.tensors[2 * li];
            let codes = layer.codes.to_vec();
            assert_eq!(w.len(), codes.len());
            let mut err_sum = 0.0f64;
            let mut n_off_rail = 0usize;
            for (i, (&orig, &code)) in w.data().iter().zip(&codes).enumerate() {
                // shared clamping rule: codes are exactly QParams::quantize_i8
                assert_eq!(code, layer.w_qp.quantize_i8(orig), "case {case} layer {li} idx {i}");
                if code > -128 && code < 127 {
                    let err = (layer.w_qp.dequantize_i8(code) - orig).abs();
                    assert!(
                        err <= layer.w_qp.delta + 1e-6,
                        "case {case} layer {li} idx {i}: err {err} > delta {}",
                        layer.w_qp.delta
                    );
                    err_sum += err as f64;
                    n_off_rail += 1;
                }
            }
            if n_off_rail > 32 {
                let mean = err_sum / n_off_rail as f64;
                assert!(
                    mean <= 0.75 * layer.w_qp.delta as f64,
                    "case {case} layer {li}: mean err {mean} vs delta {}",
                    layer.w_qp.delta
                );
            }
        }
        // biases ride along in fp32, untouched
        for (li, layer) in eng.layers.iter().enumerate() {
            assert_eq!(&layer.b[..], p.tensors[2 * li + 1].data());
        }
    }
}

#[test]
fn prop_fp32_broadcast_is_lossless() {
    let p = mlp_params(&[6, 24, 3], 77);
    let bc = ParamBroadcast::new(&p, Precision::Fp32).unwrap();
    let snap = bc.latest();
    let ActorEngine::F32(ref eng) = snap.engine else {
        panic!("fp32 precision must publish the fp32 engine");
    };
    for (li, layer) in eng.layers.iter().enumerate() {
        assert_eq!(&layer.w[..], p.tensors[2 * li].data());
        assert_eq!(&layer.b[..], p.tensors[2 * li + 1].data());
    }
}

// ----------------------------------------------------------- version monotone

#[test]
fn prop_versions_monotone_under_concurrent_publishers() {
    const PUBLISHERS: usize = 4;
    const PUBLISHES_EACH: usize = 25;
    const READERS: usize = 3;

    let base = mlp_params(&[4, 16, 2], 9);
    let bc = Arc::new(ParamBroadcast::new(&base, Precision::Int(8)).unwrap());
    let done = Arc::new(AtomicBool::new(false));

    // Readers poll version() and latest() as fast as they can, recording
    // every observation; each trace must be non-decreasing and snapshots
    // must never lag the version counter they were read after.
    let readers: Vec<_> = (0..READERS)
        .map(|_| {
            let bc = bc.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut trace: Vec<u64> = Vec::new();
                let mut last_snap = 0u64;
                while !done.load(Ordering::Relaxed) {
                    let v = bc.version();
                    let snap = bc.latest();
                    assert!(
                        snap.version >= v,
                        "snapshot {} older than version counter {v}",
                        snap.version
                    );
                    assert!(snap.version >= last_snap, "snapshot version went backwards");
                    last_snap = snap.version;
                    trace.push(v);
                }
                trace
            })
        })
        .collect();

    let publishers: Vec<_> = (0..PUBLISHERS)
        .map(|k| {
            let bc = bc.clone();
            let params = mlp_params(&[4, 16, 2], 100 + k as u64);
            std::thread::spawn(move || {
                for _ in 0..PUBLISHES_EACH {
                    bc.publish(&params).unwrap();
                }
            })
        })
        .collect();

    for p in publishers {
        p.join().unwrap();
    }
    done.store(true, Ordering::Relaxed);
    for r in readers {
        let trace = r.join().unwrap();
        for w in trace.windows(2) {
            assert!(w[0] <= w[1], "observed version regressed: {} -> {}", w[0], w[1]);
        }
    }
    // every publish got a distinct, dense version number
    assert_eq!(bc.version(), (PUBLISHERS * PUBLISHES_EACH) as u64);
    assert_eq!(bc.latest().version, bc.version());
}

#[test]
fn prop_publish_returns_strictly_increasing_versions_per_thread() {
    const THREADS: usize = 4;
    const EACH: usize = 20;
    let base = mlp_params(&[4, 8, 2], 3);
    let bc = Arc::new(ParamBroadcast::new(&base, Precision::Fp32).unwrap());
    let handles: Vec<_> = (0..THREADS)
        .map(|k| {
            let bc = bc.clone();
            let params = mlp_params(&[4, 8, 2], 200 + k as u64);
            std::thread::spawn(move || {
                let mut versions = Vec::with_capacity(EACH);
                for _ in 0..EACH {
                    versions.push(bc.publish(&params).unwrap());
                }
                versions
            })
        })
        .collect();
    let mut all: Vec<u64> = Vec::new();
    for h in handles {
        let vs = h.join().unwrap();
        for w in vs.windows(2) {
            assert!(w[0] < w[1], "per-thread publish versions must strictly increase");
        }
        all.extend(vs);
    }
    // versions are globally unique and cover 1..=THREADS*EACH
    all.sort();
    let want: Vec<u64> = (1..=(THREADS * EACH) as u64).collect();
    assert_eq!(all, want);
}

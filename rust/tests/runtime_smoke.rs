//! Integration: load real AOT artifacts and execute them through PJRT.
//!
//! Requires `make artifacts` to have run (skipped otherwise, as in CI
//! without the python toolchain).

use quarl::rng::Pcg32;
use quarl::runtime::{ParamSet, Runtime};
use quarl::tensor::Tensor;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn act_program_runs_and_is_deterministic() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let arch = rt.manifest.arch_for("dqn/cartpole").unwrap().to_string();
    let act = rt.load(&format!("{arch}_act")).unwrap();

    let n_params = act.spec.count("n_params").unwrap();
    let mut rng = Pcg32::new(7, 1);
    let params = ParamSet::init(&act.spec.inputs[..n_params], &mut rng);

    let n_q = act.spec.n_qstate;
    let obs_spec = &act.spec.inputs[act.spec.input_index("obs").unwrap()];
    let mut inputs: Vec<Tensor> = params.tensors.clone();
    inputs.push(Tensor::zeros(vec![n_q, 2]));
    inputs.push(Tensor::full(obs_spec.shape.clone(), 0.1));
    inputs.push(Tensor::vec1(&[0.0, 0.0, 1000.0]));

    let out1 = act.run(&inputs).unwrap();
    let out2 = act.run(&inputs).unwrap();
    assert_eq!(out1.len(), 1);
    assert_eq!(out1[0].shape(), &[1, 2]);
    assert_eq!(out1[0].data(), out2[0].data(), "program must be pure");
    assert!(out1[0].data().iter().all(|x| x.is_finite()));
}

#[test]
fn train_program_updates_params_and_reduces_td() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let arch = rt.manifest.arch_for("dqn/cartpole").unwrap().to_string();
    let train = rt.load(&format!("{arch}_train")).unwrap();
    let spec = &train.spec;
    let n_params = spec.count("n_params").unwrap();
    let b = spec.arch.train_batch;
    let obs_dim = spec.arch.obs_dim;

    let mut rng = Pcg32::new(11, 1);
    let params = ParamSet::init(&spec.inputs[..n_params], &mut rng);
    let zeros = params.zeros_like();

    // inputs: params, target, m, v, qstate, obs, act, rew, nobs, done, isw, hyper
    let mut inputs: Vec<Tensor> = Vec::new();
    inputs.extend(params.tensors.clone());
    inputs.extend(params.tensors.clone()); // target = online
    inputs.extend(zeros.tensors.clone());
    inputs.extend(zeros.tensors.clone());
    inputs.push(Tensor::zeros(vec![spec.n_qstate, 2]));
    let obs: Vec<f32> = (0..b * obs_dim).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
    inputs.push(Tensor::new(vec![b, obs_dim], obs.clone()).unwrap());
    inputs.push(Tensor::vec1(&vec![0.0; b]));
    inputs.push(Tensor::vec1(&vec![1.0; b])); // reward 1 everywhere
    inputs.push(Tensor::new(vec![b, obs_dim], obs).unwrap());
    inputs.push(Tensor::vec1(&vec![0.0; b]));
    inputs.push(Tensor::vec1(&vec![1.0; b])); // uniform importance weights
    inputs.push(Tensor::vec1(&[1e-3, 0.99, 0.0, 0.0, 1e9, 1.0]));

    let out = train.run(&inputs).unwrap();
    assert_eq!(out.len(), spec.outputs.len());
    let loss0 = out[spec.output_index("loss").unwrap()].data()[0];
    assert!(loss0.is_finite() && loss0 > 0.0, "loss {loss0}");

    // Step 50 times feeding params back; TD loss on the fixed batch must drop.
    let mut cur = out;
    for t in 2..50 {
        for i in 0..n_params {
            inputs[i] = cur[i].clone(); // online params
            inputs[2 * n_params + i] = cur[n_params + i].clone(); // m
            inputs[3 * n_params + i] = cur[2 * n_params + i].clone(); // v
        }
        let h = inputs.last_mut().unwrap();
        h.data_mut()[5] = t as f32;
        cur = train.run(&inputs).unwrap();
    }
    let loss_n = cur[spec.output_index("loss").unwrap()].data()[0];
    assert!(
        loss_n < loss0 * 0.5,
        "training on a fixed batch should reduce loss: {loss0} -> {loss_n}"
    );
}

//! Snapshot round-trip harness: the distribution contract ActorQ's
//! second transport rests on — a snapshot written at any supported
//! precision and fetched over the wire hydrates an engine bit-identical
//! to the source in both forward paths, and any corrupted, truncated,
//! or stale blob is detected client-side as a typed error *before* an
//! engine is built. All networking is loopback; nothing here depends on
//! real-network timing.

use quarl::inference::{Engine, EngineConfig, EngineF32, EngineQuant};
use quarl::quant::Precision;
use quarl::rng::Pcg32;
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;
use quarl::snapshot::{
    Artifact, SnapshotClient, SnapshotError, SnapshotHub, SnapshotServer, HEADER_LEN,
};
use std::sync::Arc;

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

/// Source engine + its artifact at `version`, for every supported
/// precision label ("fp32", "int1".."int8", "ternary").
fn artifact_for(p: &ParamSet, precision: Precision, version: u64) -> Artifact {
    match precision {
        Precision::Fp32 => {
            Artifact::from_engine_f32(&EngineF32::from_params(p).unwrap(), version)
        }
        _ => Artifact::from_engine_quant(
            &EngineQuant::from_params_prec(p, precision, EngineConfig::default()).unwrap(),
            version,
        ),
    }
}

fn artifact_for_bits(p: &ParamSet, bits: Option<u32>, version: u64) -> Artifact {
    artifact_for(p, bits.map_or(Precision::Fp32, Precision::Int), version)
}

/// Drive `n` random observations through both engines and demand
/// bit-equality on the scalar AND batched paths.
fn assert_bit_identical<A: Engine + ?Sized, B: Engine + ?Sized>(
    src: &mut A,
    dst: &mut B,
    din: usize,
    dout: usize,
    seed: u64,
) {
    let mut rng = Pcg32::new(seed, 9);
    let batch = 5;
    let xs: Vec<f32> = (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
    let mut a = vec![0.0f32; dout];
    let mut b = vec![0.0f32; dout];
    for r in 0..batch {
        let x = &xs[r * din..(r + 1) * din];
        src.forward(x, &mut a).unwrap();
        dst.forward(x, &mut b).unwrap();
        assert_eq!(a, b, "scalar row {r}");
    }
    let mut ab = vec![0.0f32; batch * dout];
    let mut bb = vec![0.0f32; batch * dout];
    src.forward_batch(&xs, batch, &mut ab).unwrap();
    dst.forward_batch(&xs, batch, &mut bb).unwrap();
    for (k, (x, y)) in ab.iter().zip(&bb).enumerate() {
        assert!(
            x == y,
            "batched element {k}: src {x} ({:#x}) vs rebuilt {y} ({:#x})",
            x.to_bits(),
            y.to_bits()
        );
    }
}

#[test]
fn every_precision_round_trips_over_the_wire_bit_identically() {
    // fp32, every packed width 1..=8, and ternary through the full
    // pipeline: write -> publish -> serve -> fetch -> rebuild. One
    // server, ten successive versions.
    let dims = [6usize, 24, 10, 3];
    let p = mlp_params(&dims, 11);
    let hub = Arc::new(SnapshotHub::new());
    let server = SnapshotServer::bind("127.0.0.1:0", Arc::clone(&hub)).unwrap();
    let client = SnapshotClient::new(server.addr());

    // fp32, the affine widths, and both bitplane formats — int1 and
    // ternary exercise the sign/mask plane payload sections, and
    // ternary additionally pins the label-authoritative manifest decode
    // (it shares bits=2 with the affine crumb codec).
    let precisions: Vec<Precision> = std::iter::once(Precision::Fp32)
        .chain((1..=8).map(Precision::Int))
        .chain(std::iter::once(Precision::Ternary))
        .collect();
    for (i, precision) in precisions.into_iter().enumerate() {
        let version = (i + 1) as u64;
        let art = artifact_for(&p, precision, version);
        hub.publish(&art).unwrap();
        assert_eq!(client.version().unwrap(), version);

        let (got_version, mut remote) =
            client.fetch_engine(EngineConfig::default()).unwrap();
        assert_eq!(got_version, version);
        match precision {
            Precision::Fp32 => {
                let mut src = EngineF32::from_params(&p).unwrap();
                assert_bit_identical(&mut src, &mut remote, dims[0], dims[3], 500 + version);
            }
            _ => {
                let mut src =
                    EngineQuant::from_params_prec(&p, precision, EngineConfig::default())
                        .unwrap();
                assert_bit_identical(&mut src, &mut remote, dims[0], dims[3], 500 + version);
            }
        }
    }
}

#[test]
fn bitplane_blobs_survive_byte_flips_and_truncation_as_typed_errors() {
    // The PR-9 wire contract for the sign/mask plane payloads: a
    // bits=1 (and ternary) artifact must reject EVERY single-byte flip
    // (all bits and just the low bit — the low-bit case is what a
    // silent sign-plane corruption looks like) and EVERY truncated
    // prefix with a typed SnapshotError, never a panic and never a
    // silently-built engine. Ternary's dual planes carry the extra
    // sign-outside-mask / nonzero-pad structure; any flip that slips
    // past the section CRC would have to also survive those validators.
    for precision in [Precision::Int(1), Precision::Ternary] {
        // Odd in_dim straddles a plane-word boundary; 3 output cols
        // keep per-column strides unaligned.
        let p = mlp_params(&[5, 67, 3], 26);
        let blob = artifact_for(&p, precision, 4).to_bytes();
        assert!(
            Artifact::from_bytes(&blob).is_ok(),
            "pristine {} blob must parse",
            precision.label()
        );

        for mask in [0xFFu8, 0x01] {
            for off in 0..blob.len() {
                let mut bad = blob.clone();
                bad[off] ^= mask;
                assert!(
                    Artifact::from_bytes(&bad).is_err(),
                    "{}: flip mask {mask:#04x} at offset {off} went undetected",
                    precision.label()
                );
            }
        }
        for len in 0..blob.len() {
            assert!(
                Artifact::from_bytes(&blob[..len]).is_err(),
                "{}: truncation to {len}/{} bytes went undetected",
                precision.label(),
                blob.len()
            );
        }

        // Round trip over the real wire too: publish, fetch, rebuild,
        // and demand bit-identity with the in-process source engine.
        let hub = Arc::new(SnapshotHub::new());
        hub.publish(&Artifact::from_bytes(&blob).unwrap()).unwrap();
        let server = SnapshotServer::bind("127.0.0.1:0", Arc::clone(&hub)).unwrap();
        let client = SnapshotClient::new(server.addr());
        let (version, mut remote) = client.fetch_engine(EngineConfig::default()).unwrap();
        assert_eq!(version, 4);
        let mut src =
            EngineQuant::from_params_prec(&p, precision, EngineConfig::default()).unwrap();
        assert_bit_identical(&mut src, &mut remote, 5, 3, 600);
    }
}

#[test]
fn every_single_byte_flip_is_detected() {
    // Blanket fault injection: flipping ANY byte of the blob (all bits,
    // and just the low bit) must surface as a typed error from
    // validation — never a panic, never a silently-built engine. Every
    // region is covered by a checksum or a structural check: magic,
    // format, header version (cross-checked against the manifest),
    // manifest length + CRC, manifest bytes, payload section CRCs.
    let p = mlp_params(&[4, 6, 2], 21);
    let art = artifact_for_bits(&p, Some(4), 3);
    let blob = art.to_bytes();
    assert!(Artifact::from_bytes(&blob).is_ok(), "pristine blob must parse");

    for mask in [0xFFu8, 0x01] {
        for off in 0..blob.len() {
            let mut bad = blob.clone();
            bad[off] ^= mask;
            let err = Artifact::from_bytes(&bad);
            assert!(
                err.is_err(),
                "flip mask {mask:#04x} at offset {off} went undetected"
            );
        }
    }

    // Targeted variants: the error is not just "some error", specific
    // corruptions map to specific types.
    let mut bad_magic = blob.clone();
    bad_magic[0] ^= 0xFF;
    assert!(matches!(Artifact::from_bytes(&bad_magic), Err(SnapshotError::BadMagic)));

    let mut bad_format = blob.clone();
    bad_format[4] ^= 0xFF;
    assert!(matches!(
        Artifact::from_bytes(&bad_format),
        Err(SnapshotError::UnsupportedFormat(_))
    ));

    let mut skewed_version = blob.clone();
    skewed_version[8] ^= 0x01;
    assert!(matches!(
        Artifact::from_bytes(&skewed_version),
        Err(SnapshotError::VersionMismatch { .. })
    ));

    let mut bad_payload = blob.clone();
    let last = bad_payload.len() - 1;
    bad_payload[last] ^= 0xFF;
    assert!(matches!(
        Artifact::from_bytes(&bad_payload),
        Err(SnapshotError::ChecksumMismatch { .. })
    ));
}

#[test]
fn truncation_at_every_prefix_is_detected() {
    let p = mlp_params(&[4, 6, 2], 22);
    let blob = artifact_for_bits(&p, Some(2), 1).to_bytes();
    for len in 0..blob.len() {
        let err = Artifact::from_bytes(&blob[..len]);
        assert!(err.is_err(), "truncation to {len}/{} bytes went undetected", blob.len());
    }
    assert!(Artifact::from_bytes(&blob).is_ok());
}

#[test]
fn corrupted_blob_served_over_the_wire_fails_client_side() {
    // The hub deliberately validates only the header on publish_bytes,
    // so a corrupted payload can be *served* — the client must catch it
    // after the fetch, before any engine exists.
    let p = mlp_params(&[5, 12, 3], 23);
    let mut blob = artifact_for_bits(&p, Some(6), 1).to_bytes();
    let last = blob.len() - 1;
    blob[last] ^= 0xFF;

    let hub = Arc::new(SnapshotHub::new());
    hub.publish_bytes(blob).unwrap();
    let server = SnapshotServer::bind("127.0.0.1:0", Arc::clone(&hub)).unwrap();
    let client = SnapshotClient::new(server.addr());

    match client.fetch() {
        Err(SnapshotError::ChecksumMismatch { section, .. }) => {
            assert!(section.contains("layer"), "corrupt payload pinpointed, got {section}");
        }
        other => panic!("corrupted fetch must be a checksum error, got {other:?}"),
    }
    assert!(client.fetch_engine(EngineConfig::default()).is_err());
}

#[test]
fn stale_version_pins_are_typed() {
    let p = mlp_params(&[4, 6, 2], 24);
    let hub = Arc::new(SnapshotHub::new());
    hub.publish(&artifact_for_bits(&p, Some(4), 7)).unwrap();
    let server = SnapshotServer::bind("127.0.0.1:0", Arc::clone(&hub)).unwrap();
    let client = SnapshotClient::new(server.addr());

    // Pinning the live version succeeds; pinning an older one is Stale.
    assert!(client.fetch_range(0, Some(7)).is_ok());
    match client.fetch_range(0, Some(6)) {
        Err(SnapshotError::Stale { requested: 6, current: 7 }) => {}
        other => panic!("stale pin must be typed, got {other:?}"),
    }
}

#[test]
fn resumed_fetch_completes_from_a_partial_file() {
    let dims = [6usize, 24, 3];
    let p = mlp_params(&dims, 25);
    let hub = Arc::new(SnapshotHub::new());
    hub.publish(&artifact_for_bits(&p, Some(4), 9)).unwrap();
    let server = SnapshotServer::bind("127.0.0.1:0", Arc::clone(&hub)).unwrap();
    let client = SnapshotClient::new(server.addr());

    let (_, blob) = hub.latest().unwrap();
    let dir = std::env::temp_dir().join("quarl_snapshot_roundtrip_test");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resumed.qsnp");

    // A previous attempt died partway through: the .part prefix holds
    // the header (so the version is pinned) plus some payload.
    let cut = blob.len() / 3;
    assert!(cut >= HEADER_LEN, "partial prefix must include the header");
    std::fs::write(dir.join("resumed.qsnp.part"), &blob[..cut]).unwrap();

    let stats = client.fetch_to_file(&path).unwrap();
    assert!(stats.resumed, "prefix must be reused, not discarded");
    assert_eq!(stats.version, 9);
    assert_eq!(stats.total_bytes, blob.len());
    assert_eq!(stats.fetched_bytes, blob.len() - cut, "only the tail crosses the wire");
    assert!(!dir.join("resumed.qsnp.part").exists(), "part file consumed");

    // The assembled file is a verified artifact that hydrates the same
    // engine the source holds.
    let art = Artifact::read_file(&path).unwrap();
    assert_eq!(art.version, 9);
    let mut src = EngineQuant::from_params(&p, 4).unwrap();
    let mut rebuilt = art.build_engine(EngineConfig::default()).unwrap();
    assert_bit_identical(&mut src, &mut rebuilt, dims[0], dims[2], 42);
    std::fs::remove_dir_all(&dir).ok();
}

//! Golden parity: the Rust affine/fp16 quantizers must match the Python
//! oracle (python/compile/kernels/ref.py) bit-for-bit.
//!
//! Vectors generated with numpy seed 42 via ref.fake_quant_dynamic_ref /
//! ref.fp16_quant_ref — see the command in the repo history; regenerate
//! with `python -m tests.gen_golden` if the quantizer spec ever changes.

use quarl::quant::{fake_quant_slice, fp16_quant_slice};

pub const GOLDEN_X: [f32; 16] = [
    1.0180190801620483,
    -1.2679729461669922,
    1.7757670879364014,
    2.0989599227905273,
    -2.8167598247528076,
    -1.7137051820755005,
    0.717328667640686,
    -0.03761240839958191,
    0.4714380204677582,
    -0.9501746892929077,
    1.99497652053833,
    1.8222463130950928,
    0.6122521758079529,
    2.4163100719451904,
    1.294765830039978,
    -0.9607971906661987,
];
pub const GOLDEN_INT2: [f32; 16] = [
    0.0,
    -1.3082674741744995,
    1.3082674741744995,
    1.3082674741744995,
    -2.616534948348999,
    -2.616534948348999,
    0.0,
    -1.3082674741744995,
    0.0,
    -1.3082674741744995,
    1.3082674741744995,
    1.3082674741744995,
    0.0,
    1.3082674741744995,
    0.0,
    -1.3082674741744995,
];
pub const GOLDEN_INT4: [f32; 16] = [
    0.9812005758285522,
    -1.3082674741744995,
    1.6353343725204468,
    1.9624011516571045,
    -2.616534948348999,
    -1.9624011516571045,
    0.6541337370872498,
    -0.3270668685436249,
    0.3270668685436249,
    -0.9812005758285522,
    1.9624011516571045,
    1.6353343725204468,
    0.3270668685436249,
    2.2894680500030518,
    0.9812005758285522,
    -0.9812005758285522,
];
pub const GOLDEN_INT8: [f32; 16] = [
    1.0016422271728516,
    -1.2878258228302002,
    1.7579843997955322,
    2.0850512981414795,
    -2.8005101680755615,
    -1.7171010971069336,
    0.7154587507247925,
    -0.04088335856795311,
    0.4701586365699768,
    -0.9607589244842529,
    1.9828429222106934,
    1.8193094730377197,
    0.592808723449707,
    2.4121181964874268,
    1.2878258228302002,
    -0.9812005758285522,
];
pub const GOLDEN_FP16: [f32; 16] = [
    1.017578125,
    -1.267578125,
    1.775390625,
    2.099609375,
    -2.81640625,
    -1.7138671875,
    0.71728515625,
    -0.03759765625,
    0.471435546875,
    -0.9501953125,
    1.9951171875,
    1.822265625,
    0.6123046875,
    2.416015625,
    1.294921875,
    -0.9609375,
];

#[test]
fn affine_matches_python_oracle_bit_exact() {
    for (bits, want) in [(2u32, GOLDEN_INT2), (4, GOLDEN_INT4), (8, GOLDEN_INT8)] {
        let mut got = GOLDEN_X;
        fake_quant_slice(&mut got, bits).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "int{bits} idx {i}: rust {g} vs python {w}"
            );
        }
    }
}

#[test]
fn fp16_matches_python_oracle_bit_exact() {
    let mut got = GOLDEN_X;
    fp16_quant_slice(&mut got);
    for (i, (g, w)) in got.iter().zip(&GOLDEN_FP16).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "fp16 idx {i}: rust {g} vs python {w}");
    }
}

//! Property-based tests (hand-rolled generators — no proptest offline):
//! randomized sweeps over quantizer, replay, rollout, and environment
//! invariants. Each property runs against a few hundred generated cases
//! with shrink-free reporting (the failing seed is printed).

use quarl::envs::api::{Action, ActionSpace};
use quarl::envs::registry::{make_env, ENV_IDS};
use quarl::quant::affine::QParams;
use quarl::quant::{fake_quant_slice, fp16_roundtrip};
use quarl::replay::{PrioritizedReplay, ReplayBuffer, SumTree, Transition};
use quarl::rng::Pcg32;

fn rand_vec(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_ms(0.0, scale)).collect()
}

// ---------------------------------------------------------------- quant

#[test]
fn prop_quant_near_idempotent() {
    // Re-quantizing at the same params moves a value by at most one grid
    // step. (Exact idempotence does not hold for the paper's floor-based
    // quantizer in float arithmetic: delta*(q-z)/delta can round to just
    // below an integer, and floor drops it one level.)
    let mut rng = Pcg32::new(101, 1);
    for case in 0..200 {
        let n = 1 + rng.below_usize(64);
        let bits = 2 + rng.below(10);
        let scale = 10f32.powf(rng.uniform_range(-2.0, 2.0));
        let mut xs = rand_vec(&mut rng, n, scale);
        let qp = fake_quant_slice(&mut xs, bits).unwrap();
        let once = xs.clone();
        for x in xs.iter_mut() {
            *x = qp.roundtrip(*x);
        }
        for (i, (a, b)) in once.iter().zip(&xs).enumerate() {
            assert!(
                (a - b).abs() <= qp.delta * 1.0001,
                "case {case} bits {bits} idx {i}: {a} -> {b} (delta {})",
                qp.delta
            );
        }
    }
}

#[test]
fn prop_quant_output_on_grid_and_bounded() {
    let mut rng = Pcg32::new(102, 1);
    for case in 0..200 {
        let n = 1 + rng.below_usize(64);
        let bits = 1 + rng.below(12);
        let xs = rand_vec(&mut rng, n, 3.0);
        let lo = xs.iter().copied().fold(f32::INFINITY, f32::min).min(0.0);
        let hi = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max).max(0.0);
        let mut q = xs.clone();
        let qp = fake_quant_slice(&mut q, bits).unwrap();
        for (i, &v) in q.iter().enumerate() {
            assert!(
                v >= qp.dequantize(0.0) - 1e-5 && v <= qp.dequantize(qp.levels - 1.0) + 1e-5,
                "case {case}: {v} outside representable span"
            );
            // error bounded by one grid step inside the observed range
            if xs[i] >= lo && xs[i] <= hi {
                assert!(
                    (v - xs[i]).abs() <= qp.delta + 1e-5,
                    "case {case} idx {i}: err {} > delta {}",
                    (v - xs[i]).abs(),
                    qp.delta
                );
            }
        }
    }
}

#[test]
fn prop_fp16_monotone() {
    // fp16 rounding preserves order (weak monotonicity).
    let mut rng = Pcg32::new(103, 1);
    for _ in 0..200 {
        let a = rng.normal_ms(0.0, 100.0);
        let b = rng.normal_ms(0.0, 100.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(fp16_roundtrip(lo) <= fp16_roundtrip(hi), "{lo} {hi}");
    }
}

#[test]
fn prop_qparams_zero_exact_for_any_range() {
    let mut rng = Pcg32::new(104, 1);
    for _ in 0..300 {
        let vmin = -(10f32.powf(rng.uniform_range(-3.0, 3.0)));
        let vmax = 10f32.powf(rng.uniform_range(-3.0, 3.0));
        let bits = 1 + rng.below(14);
        let qp = QParams::from_range(vmin, vmax, bits).unwrap();
        assert_eq!(qp.roundtrip(0.0), 0.0, "range [{vmin}, {vmax}] bits {bits}");
    }
}

// ---------------------------------------------------------------- replay

#[test]
fn prop_sum_tree_total_equals_sum() {
    let mut rng = Pcg32::new(105, 1);
    for _ in 0..50 {
        let cap = 1 + rng.below_usize(200);
        let mut tree = SumTree::new(cap);
        let mut direct = vec![0.0f32; cap];
        for _ in 0..300 {
            let i = rng.below_usize(cap);
            let p = rng.uniform() * 10.0;
            tree.set(i, p);
            direct[i] = p;
        }
        let want: f32 = direct.iter().sum();
        assert!((tree.total() - want).abs() <= want.abs() * 1e-4 + 1e-4);
        // find() always lands on a positive-priority leaf
        if want > 0.0 {
            for _ in 0..20 {
                let u = rng.uniform() * tree.total();
                let leaf = tree.find(u);
                assert!(direct[leaf] > 0.0, "find landed on zero-priority leaf {leaf}");
            }
        }
    }
}

#[test]
fn prop_replay_gather_consistency() {
    // Whatever is pushed comes back intact, keyed by the reward tag.
    let mut rng = Pcg32::new(106, 1);
    for _ in 0..30 {
        let cap = 8 + rng.below_usize(64);
        let obs_dim = 1 + rng.below_usize(6);
        let mut buf = ReplayBuffer::new(cap, obs_dim, 1);
        let n = rng.below_usize(2 * cap) + 1;
        for k in 0..n {
            let obs: Vec<f32> = (0..obs_dim).map(|d| (k * 10 + d) as f32).collect();
            let next: Vec<f32> = obs.iter().map(|v| v + 1.0).collect();
            buf.push(Transition {
                obs: &obs,
                action: &[(k % 4) as f32],
                reward: k as f32,
                next_obs: &next,
                done: k % 3 == 0,
            });
        }
        let b = buf.sample(16, &mut rng);
        for row in 0..16 {
            let k = b.rewards.data()[row] as usize;
            assert_eq!(b.obs.at2(row, 0), (k * 10) as f32);
            assert_eq!(b.next_obs.at2(row, 0), (k * 10) as f32 + 1.0);
            assert_eq!(b.actions.data()[row], (k % 4) as f32);
            assert_eq!(b.dones.data()[row], (k % 3 == 0) as u8 as f32);
        }
    }
}

#[test]
fn prop_per_weights_in_unit_interval() {
    let mut rng = Pcg32::new(107, 1);
    for _ in 0..20 {
        let mut per = PrioritizedReplay::new(64, 2, 1, rng.uniform_range(0.3, 1.0));
        for k in 0..40 {
            let o = [k as f32, 0.0];
            per.push(Transition { obs: &o, action: &[0.0], reward: 0.0, next_obs: &o, done: false });
        }
        let idx: Vec<usize> = (0..40).collect();
        let td: Vec<f32> = (0..40).map(|_| rng.uniform() * 5.0).collect();
        per.update_priorities(&idx, &td);
        let beta = rng.uniform();
        let b = per.sample(16, beta, &mut rng);
        for &w in b.weights.data() {
            assert!(w > 0.0 && w <= 1.0 + 1e-6, "weight {w} outside (0, 1]");
        }
    }
}

// ------------------------------------------------------------------ envs

#[test]
fn prop_every_env_contract_random_seeds() {
    let mut rng = Pcg32::new(108, 1);
    for id in ENV_IDS {
        for _ in 0..2 {
            let seed = rng.next_u64();
            let mut env = make_env(id).unwrap();
            let mut er = Pcg32::new(seed, 5);
            let mut obs = vec![0.0f32; env.obs_dim()];
            env.reset(&mut er, &mut obs);
            let space = env.action_space();
            let mut steps = 0;
            loop {
                let a = match &space {
                    ActionSpace::Discrete(n) => Action::Discrete(er.below_usize(*n)),
                    ActionSpace::Continuous(d) => Action::Continuous(
                        (0..*d).map(|_| er.uniform_range(-1.0, 1.0)).collect(),
                    ),
                };
                let s = env.step(&a, &mut er, &mut obs);
                steps += 1;
                assert!(s.reward.is_finite(), "{id} seed {seed}");
                assert!(obs.iter().all(|x| x.is_finite()), "{id} seed {seed}");
                if s.done {
                    break;
                }
                assert!(steps <= env.max_steps() + 1, "{id} seed {seed}: no done");
            }
        }
    }
}

#[test]
fn prop_env_obs_within_sane_bounds() {
    // Feature observations stay within a loose envelope — a policy's
    // quantization ranges cannot explode from env outputs.
    let mut rng = Pcg32::new(109, 1);
    for id in ENV_IDS {
        let mut env = make_env(id).unwrap();
        let mut er = Pcg32::new(7, 9);
        let mut obs = vec![0.0f32; env.obs_dim()];
        env.reset(&mut er, &mut obs);
        let space = env.action_space();
        for _ in 0..300 {
            let a = match &space {
                ActionSpace::Discrete(n) => Action::Discrete(rng.below_usize(*n)),
                ActionSpace::Continuous(d) => Action::Continuous(
                    (0..*d).map(|_| rng.uniform_range(-1.0, 1.0)).collect(),
                ),
            };
            let s = env.step(&a, &mut er, &mut obs);
            for (i, &v) in obs.iter().enumerate() {
                assert!(v.abs() < 60.0, "{id} obs[{i}] = {v} out of envelope");
            }
            if s.done {
                env.reset(&mut er, &mut obs);
            }
        }
    }
}

//! End-to-end ActorQ smoke: a 2-actor int8 actor-learner DQN run on
//! cartpole through the full Rust -> PJRT stack must reach the same
//! mean-reward floor as the synchronous driver at equal step budget.
//! Skips (like `e2e_training.rs`) when `artifacts/` is absent.

use quarl::actorq::{ActorQConfig, Precision};
use quarl::algos::dqn;
use quarl::coordinator::{evaluate, EvalMode};
use quarl::runtime::Runtime;

fn artifacts() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then(|| Runtime::new(dir).unwrap())
}

#[test]
fn actorq_int8_matches_sync_reward_floor() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = dqn::DqnConfig::new("cartpole");
    cfg.total_steps = 3_000;
    cfg.warmup = 300;
    cfg.seed = 11;

    let (sync_policy, sync_log) = dqn::train(&rt, &cfg).unwrap();
    let sync_eval = evaluate(&rt, &sync_policy, 5, EvalMode::AsTrained, 3).unwrap();

    let acfg = ActorQConfig::new(2).with_precision(Precision::Int(8));
    let (aq_policy, aq_log) = dqn::train_actorq(&rt, &cfg, &acfg).unwrap();
    let aq_eval = evaluate(&rt, &aq_policy, 5, EvalMode::AsTrained, 3).unwrap();

    // Budget accounting: the learner consumes at least the configured
    // steps (the final in-flight batch may overshoot by one flush).
    assert!(aq_log.env_steps >= cfg.total_steps, "{} env steps", aq_log.env_steps);
    // One blocking recv plus a try_drain of up to n_actors batches per
    // learner iteration bounds the overshoot.
    assert!(
        aq_log.env_steps <= cfg.total_steps + acfg.flush_every * (acfg.n_actors + 1),
        "{} env steps overshoot",
        aq_log.env_steps
    );
    // The async cadence matches the sync driver's train-step count.
    let sync_trains = (cfg.total_steps - cfg.warmup) / cfg.train_freq;
    assert!(
        aq_log.train_steps >= sync_trains * 9 / 10 && aq_log.train_steps <= sync_trains,
        "train steps {} vs sync {sync_trains}",
        aq_log.train_steps
    );
    assert!(aq_log.broadcasts > 0, "learner never published parameters");
    assert!(aq_log.episodes > 0 && sync_log.episodes > 0);

    // Convergence floor: both drivers are smoke-scale here, so the bar is
    // the e2e_training one (valid episodes) plus a same-floor comparison
    // with slack for run-to-run noise.
    assert!(sync_eval.mean_reward >= 1.0 && aq_eval.mean_reward >= 1.0);
    assert!(
        aq_eval.mean_reward >= 0.5 * sync_eval.mean_reward,
        "int8-actor reward {} fell below the sync floor {}",
        aq_eval.mean_reward,
        sync_eval.mean_reward
    );
}

#[test]
fn actorq_fp32_short_run() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = dqn::DqnConfig::new("cartpole");
    cfg.total_steps = 1_500;
    cfg.warmup = 200;
    cfg.seed = 12;
    let acfg = ActorQConfig::new(2).with_precision(Precision::Fp32);
    let (policy, log) = dqn::train_actorq(&rt, &cfg, &acfg).unwrap();
    assert!(log.env_steps >= cfg.total_steps);
    assert_eq!(log.actor_stats.len(), 2);
    let collected: usize = log.actor_stats.iter().map(|s| s.env_steps).sum();
    assert!(collected >= log.env_steps, "actors must have stepped what the learner consumed");
    let e = evaluate(&rt, &policy, 3, EvalMode::AsTrained, 2).unwrap();
    assert!(e.mean_reward.is_finite() && e.mean_reward >= 1.0);
}

#[test]
fn actorq_ddpg_short_run() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = quarl::algos::ddpg::DdpgConfig::new("pendulum");
    cfg.total_steps = 1_200;
    cfg.warmup = 300;
    cfg.seed = 13;
    let acfg = ActorQConfig::new(2).with_precision(Precision::Int(8));
    let (policy, log) = quarl::algos::ddpg::train_actorq(&rt, &cfg, &acfg).unwrap();
    assert!(log.env_steps >= cfg.total_steps);
    assert!(log.train_steps > 0 && log.broadcasts > 0);
    let e = evaluate(&rt, &policy, 2, EvalMode::AsTrained, 2).unwrap();
    assert!(e.mean_reward.is_finite() && e.mean_reward <= 0.0, "pendulum rewards are <= 0");
}

//! Engine parity harness: the property the ActorQ design rests on — the
//! int8 deployment engine's forward pass stays within the per-layer
//! quantization error bound of the fp32 engine, and the *actions* it
//! picks agree with fp32 on the overwhelming majority of observations.
//! (Hand-rolled randomized cases; no proptest offline.)

use quarl::inference::{EngineF32, EngineInt8};
use quarl::quant::QParams;
use quarl::rng::Pcg32;
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .fold((0usize, f32::NEG_INFINITY), |acc, (i, &x)| if x > acc.1 { (i, x) } else { acc })
        .0
}

#[test]
fn single_layer_error_within_quantization_bound() {
    // For one linear layer the int8 error decomposes exactly:
    //   y - y_q = sum_i (a_i w_i - a^_i w^_i)
    // with a^ = dequantized activation, w^ = dequantized (saturating) i8
    // weight, so |y - y_q| <= sum_i |a_i||w_i - w^_i| + |w^_i||a_i - a^_i|.
    // Both factors are computable from public QParams, making this a
    // rigorous per-layer bound, saturation included.
    let mut rng = Pcg32::new(301, 1);
    for case in 0..50 {
        let din = 2 + rng.below_usize(30);
        let dout = 1 + rng.below_usize(20);
        let p = mlp_params(&[din, dout], 1000 + case);
        let w = &p.tensors[0];
        let w_qp = QParams::from_range(w.min(), w.max(), 8).unwrap();

        let x: Vec<f32> = (0..din).map(|_| rng.normal_ms(0.0, 1.0)).collect();
        let amin = x.iter().copied().fold(f32::INFINITY, f32::min);
        let amax = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let a_qp = QParams::from_range(amin, amax, 8).unwrap();

        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let mut yf = vec![0.0f32; dout];
        let mut yq = vec![0.0f32; dout];
        f32e.forward(&x, &mut yf);
        i8e.forward(&x, &mut yq).unwrap();

        for c in 0..dout {
            let mut bound = 0.0f64;
            for (i, &a) in x.iter().enumerate() {
                let wv = w.data()[i * dout + c];
                let w_hat = w_qp.dequantize_i8(w_qp.quantize_i8(wv));
                let a_hat = a_qp.delta * (a_qp.quantize(a) - a_qp.zero_point);
                bound += (a.abs() * (wv - w_hat).abs()) as f64
                    + (w_hat.abs() * (a - a_hat).abs()) as f64;
            }
            let err = (yf[c] - yq[c]).abs() as f64;
            assert!(
                err <= bound + 1e-4,
                "case {case} out {c}: err {err} > bound {bound}"
            );
        }
    }
}

#[test]
fn int8_gemv_matches_dequantized_reference() {
    // The integer GEMV (i32 accumulation, combined scale on the way out)
    // must equal the real-arithmetic product of the dequantized operands
    // up to f32 rounding — i.e. the integer path adds no error beyond
    // quantization itself.
    let mut rng = Pcg32::new(302, 1);
    for case in 0..30 {
        let din = 2 + rng.below_usize(24);
        let dout = 1 + rng.below_usize(16);
        let p = mlp_params(&[din, dout], 2000 + case);
        let w = &p.tensors[0];
        let b = &p.tensors[1];
        let w_qp = QParams::from_range(w.min(), w.max(), 8).unwrap();

        let x: Vec<f32> = (0..din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let amin = x.iter().copied().fold(f32::INFINITY, f32::min);
        let amax = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let a_qp = QParams::from_range(amin, amax, 8).unwrap();

        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let mut yq = vec![0.0f32; dout];
        i8e.forward(&x, &mut yq).unwrap();

        for c in 0..dout {
            let mut acc = 0.0f64;
            for (i, &a) in x.iter().enumerate() {
                let qa = (a_qp.quantize(a) - a_qp.zero_point) as f64;
                let qw = w_qp.quantize_i8(w.data()[i * dout + c]) as f64;
                acc += qa * qw;
            }
            let want = (a_qp.delta as f64) * (w_qp.delta as f64) * acc + b.data()[c] as f64;
            let got = yq[c] as f64;
            let tol = 1e-3 * want.abs().max(1.0);
            assert!(
                (want - got).abs() <= tol,
                "case {case} out {c}: engine {got} vs reference {want}"
            );
        }
    }
}

#[test]
fn multi_layer_error_envelope() {
    // Across random 3-layer towers the aggregate int8 error stays inside
    // a conservative envelope of the output magnitude — the looser,
    // deployment-level version of the per-layer bound above.
    let mut rng = Pcg32::new(303, 1);
    for case in 0..20 {
        let hidden = 16 + rng.below_usize(64);
        let dout = 2 + rng.below_usize(8);
        let p = mlp_params(&[8, hidden, hidden / 2 + 1, dout], 3000 + case);
        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let x: Vec<f32> = (0..8).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let mut yf = vec![0.0f32; dout];
        let mut yq = vec![0.0f32; dout];
        f32e.forward(&x, &mut yf);
        i8e.forward(&x, &mut yq).unwrap();
        assert!(yq.iter().all(|v| v.is_finite()), "case {case}: non-finite int8 output");
        let scale = yf.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1e-2);
        let mean_err: f32 =
            yf.iter().zip(&yq).map(|(a, b)| (a - b).abs()).sum::<f32>() / (dout as f32 * scale);
        assert!(mean_err < 0.2, "case {case}: mean relative error {mean_err}");
    }
}

#[test]
fn argmax_agreement_exceeds_95pct_on_cartpole_scale() {
    // The deployment criterion: across random cartpole-shaped policies
    // and cartpole-scale observations, the int8 actor must pick the same
    // action as the fp32 actor > 95% of the time — the property that
    // lets ActorQ swap int8 actors in without changing what is learned.
    let mut agree = 0usize;
    let mut trials = 0usize;
    for seed in [11u64, 23, 47] {
        let p = mlp_params(&[4, 64, 64, 2], seed);
        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let mut rng = Pcg32::new(seed ^ 0xA5, 9);
        for _ in 0..300 {
            // cartpole observation envelope: positions small, velocities larger
            let x = [
                rng.uniform_range(-2.4, 2.4),
                rng.uniform_range(-3.0, 3.0),
                rng.uniform_range(-0.21, 0.21),
                rng.uniform_range(-3.0, 3.0),
            ];
            let mut yf = vec![0.0f32; 2];
            let mut yq = vec![0.0f32; 2];
            f32e.forward(&x, &mut yf);
            i8e.forward(&x, &mut yq).unwrap();
            trials += 1;
            if argmax(&yf) == argmax(&yq) {
                agree += 1;
            }
        }
    }
    assert!(
        agree * 100 >= trials * 95,
        "argmax agreement {agree}/{trials} below 95%"
    );
}

#[test]
fn parity_holds_for_narrow_and_wide_towers() {
    // Shape sweep: the parity property is architecture-independent.
    let mut rng = Pcg32::new(305, 1);
    for dims in [vec![4, 16, 2], vec![12, 128, 64, 5], vec![6, 32, 32, 32, 3]] {
        let p = mlp_params(&dims, 4242);
        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let dout = *dims.last().unwrap();
        let din = dims[0];
        let mut agree = 0usize;
        let trials = 100usize;
        for _ in 0..trials {
            let x: Vec<f32> = (0..din).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
            let mut yf = vec![0.0f32; dout];
            let mut yq = vec![0.0f32; dout];
            f32e.forward(&x, &mut yf);
            i8e.forward(&x, &mut yq).unwrap();
            if argmax(&yf) == argmax(&yq) {
                agree += 1;
            }
        }
        assert!(agree * 10 >= trials * 9, "dims {dims:?}: agreement {agree}/{trials}");
    }
}

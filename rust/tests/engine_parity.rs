//! Engine parity harness: the properties the ActorQ design rests on —
//! the int8 deployment engine's forward pass stays within the per-layer
//! quantization error bound of the fp32 engine, the *actions* it picks
//! agree with fp32 on the overwhelming majority of observations, the
//! batched GEMM path is bit-identical per row to the scalar GEMV path
//! for both engines, and the packed kernels — affine panels and the
//! int1/ternary XNOR-popcount bitplanes alike — reproduce their scalar
//! fake-quant / sign-arithmetic references bit for bit at every thread
//! count. (Hand-rolled randomized cases; no proptest offline.)

use quarl::inference::engine_quant::{act_bitplane_params, bitplane_out};
use quarl::inference::{
    Engine, EngineConfig, EngineF32, EngineInt4, EngineInt8, EngineQuant, KernelKind,
};
use quarl::quant::{binarize, ternarize, Precision, QParams};
use quarl::rng::Pcg32;
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;
use quarl::snapshot::Artifact;
use quarl::tensor::argmax;

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

#[test]
fn single_layer_error_within_quantization_bound() {
    // For one linear layer the int8 error decomposes exactly:
    //   y - y_q = sum_i (a_i w_i - a^_i w^_i)
    // with a^ = dequantized activation, w^ = dequantized (saturating) i8
    // weight, so |y - y_q| <= sum_i |a_i||w_i - w^_i| + |w^_i||a_i - a^_i|.
    // Both factors are computable from public QParams, making this a
    // rigorous per-layer bound, saturation included.
    let mut rng = Pcg32::new(301, 1);
    for case in 0..50 {
        let din = 2 + rng.below_usize(30);
        let dout = 1 + rng.below_usize(20);
        let p = mlp_params(&[din, dout], 1000 + case);
        let w = &p.tensors[0];
        let w_qp = QParams::from_range(w.min(), w.max(), 8).unwrap();

        let x: Vec<f32> = (0..din).map(|_| rng.normal_ms(0.0, 1.0)).collect();
        let amin = x.iter().copied().fold(f32::INFINITY, f32::min);
        let amax = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let a_qp = QParams::from_range(amin, amax, 8).unwrap();

        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let mut yf = vec![0.0f32; dout];
        let mut yq = vec![0.0f32; dout];
        f32e.forward(&x, &mut yf);
        i8e.forward(&x, &mut yq).unwrap();

        for c in 0..dout {
            let mut bound = 0.0f64;
            for (i, &a) in x.iter().enumerate() {
                let wv = w.data()[i * dout + c];
                let w_hat = w_qp.dequantize_i8(w_qp.quantize_i8(wv));
                let a_hat = a_qp.delta * (a_qp.quantize(a) - a_qp.zero_point);
                bound += (a.abs() * (wv - w_hat).abs()) as f64
                    + (w_hat.abs() * (a - a_hat).abs()) as f64;
            }
            let err = (yf[c] - yq[c]).abs() as f64;
            assert!(
                err <= bound + 1e-4,
                "case {case} out {c}: err {err} > bound {bound}"
            );
        }
    }
}

#[test]
fn int8_gemv_matches_dequantized_reference() {
    // The integer GEMV (i32 accumulation, combined scale on the way out)
    // must equal the real-arithmetic product of the dequantized operands
    // up to f32 rounding — i.e. the integer path adds no error beyond
    // quantization itself.
    let mut rng = Pcg32::new(302, 1);
    for case in 0..30 {
        let din = 2 + rng.below_usize(24);
        let dout = 1 + rng.below_usize(16);
        let p = mlp_params(&[din, dout], 2000 + case);
        let w = &p.tensors[0];
        let b = &p.tensors[1];
        let w_qp = QParams::from_range(w.min(), w.max(), 8).unwrap();

        let x: Vec<f32> = (0..din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let amin = x.iter().copied().fold(f32::INFINITY, f32::min);
        let amax = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let a_qp = QParams::from_range(amin, amax, 8).unwrap();

        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let mut yq = vec![0.0f32; dout];
        i8e.forward(&x, &mut yq).unwrap();

        for c in 0..dout {
            let mut acc = 0.0f64;
            for (i, &a) in x.iter().enumerate() {
                let qa = (a_qp.quantize(a) - a_qp.zero_point) as f64;
                let qw = w_qp.quantize_i8(w.data()[i * dout + c]) as f64;
                acc += qa * qw;
            }
            let want = (a_qp.delta as f64) * (w_qp.delta as f64) * acc + b.data()[c] as f64;
            let got = yq[c] as f64;
            let tol = 1e-3 * want.abs().max(1.0);
            assert!(
                (want - got).abs() <= tol,
                "case {case} out {c}: engine {got} vs reference {want}"
            );
        }
    }
}

#[test]
fn multi_layer_error_envelope() {
    // Across random 3-layer towers the aggregate int8 error stays inside
    // a conservative envelope of the output magnitude — the looser,
    // deployment-level version of the per-layer bound above.
    let mut rng = Pcg32::new(303, 1);
    for case in 0..20 {
        let hidden = 16 + rng.below_usize(64);
        let dout = 2 + rng.below_usize(8);
        let p = mlp_params(&[8, hidden, hidden / 2 + 1, dout], 3000 + case);
        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let x: Vec<f32> = (0..8).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let mut yf = vec![0.0f32; dout];
        let mut yq = vec![0.0f32; dout];
        f32e.forward(&x, &mut yf);
        i8e.forward(&x, &mut yq).unwrap();
        assert!(yq.iter().all(|v| v.is_finite()), "case {case}: non-finite int8 output");
        let scale = yf.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1e-2);
        let mean_err: f32 =
            yf.iter().zip(&yq).map(|(a, b)| (a - b).abs()).sum::<f32>() / (dout as f32 * scale);
        assert!(mean_err < 0.2, "case {case}: mean relative error {mean_err}");
    }
}

#[test]
fn argmax_agreement_exceeds_95pct_on_cartpole_scale() {
    // The deployment criterion: across random cartpole-shaped policies
    // and cartpole-scale observations, the int8 actor must pick the same
    // action as the fp32 actor > 95% of the time — the property that
    // lets ActorQ swap int8 actors in without changing what is learned.
    let mut agree = 0usize;
    let mut trials = 0usize;
    for seed in [11u64, 23, 47] {
        let p = mlp_params(&[4, 64, 64, 2], seed);
        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let mut rng = Pcg32::new(seed ^ 0xA5, 9);
        for _ in 0..300 {
            // cartpole observation envelope: positions small, velocities larger
            let x = [
                rng.uniform_range(-2.4, 2.4),
                rng.uniform_range(-3.0, 3.0),
                rng.uniform_range(-0.21, 0.21),
                rng.uniform_range(-3.0, 3.0),
            ];
            let mut yf = vec![0.0f32; 2];
            let mut yq = vec![0.0f32; 2];
            f32e.forward(&x, &mut yf);
            i8e.forward(&x, &mut yq).unwrap();
            trials += 1;
            if argmax(&yf) == argmax(&yq) {
                agree += 1;
            }
        }
    }
    assert!(
        agree * 100 >= trials * 95,
        "argmax agreement {agree}/{trials} below 95%"
    );
}

#[test]
fn batched_path_bit_exact_with_scalar_path() {
    // The property the consumer refactor rests on: forward_batch must be
    // bit-identical per row to forward for BOTH engines, across random
    // shapes and batch sizes — int8 because the integer sums are exact
    // and the float epilogue is the same expression, fp32 because the
    // batched kernel reproduces the scalar accumulation order. Inputs
    // are pushed through a relu tower, so dead-unit rows (exact zeros,
    // degenerate ranges) occur naturally along the way.
    let mut rng = Pcg32::new(601, 1);
    let shapes: [&[usize]; 4] = [
        &[4, 16, 2],
        &[12, 64, 64, 5],
        &[7, 33, 19, 3],
        &[128, 512, 512, 25],
    ];
    for (case, dims) in shapes.iter().enumerate() {
        let p = mlp_params(dims, 6000 + case as u64);
        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let din = dims[0];
        let dout = *dims.last().unwrap();
        // The big tower only runs the acceptance batch; the small ones
        // sweep odd/small batches too (scratch-arena regrowth included).
        let batch_sizes: &[usize] = if din >= 128 { &[1, 64] } else { &[1, 2, 7, 64] };
        for &batch in batch_sizes {
            let xs: Vec<f32> =
                (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();

            let mut want = vec![0.0f32; batch * dout];
            for r in 0..batch {
                let (row_in, row_out) =
                    (&xs[r * din..(r + 1) * din], &mut want[r * dout..(r + 1) * dout]);
                f32e.forward(row_in, row_out);
            }
            let mut got = vec![0.0f32; batch * dout];
            f32e.forward_batch(&xs, batch, &mut got).unwrap();
            for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!(
                    a == b,
                    "fp32 case {case} batch {batch} element {k}: scalar {a} ({:#x}) vs batched {b} ({:#x})",
                    a.to_bits(),
                    b.to_bits()
                );
            }

            for r in 0..batch {
                let (row_in, row_out) =
                    (&xs[r * din..(r + 1) * din], &mut want[r * dout..(r + 1) * dout]);
                i8e.forward(row_in, row_out).unwrap();
            }
            i8e.forward_batch(&xs, batch, &mut got).unwrap();
            for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!(
                    a == b,
                    "int8 case {case} batch {batch} element {k}: scalar {a} ({:#x}) vs batched {b} ({:#x})",
                    a.to_bits(),
                    b.to_bits()
                );
            }
        }
    }
}

#[test]
fn degenerate_activation_range_skips_gemv_instead_of_failing() {
    // Pin the degenerate-range contract: an all-zero activation row
    // (every unit dead after relu, or an all-zero observation) has
    // amin == amax == 0 — no dynamic range to quantize against. The
    // engine must treat it as all-zero-point codes (zero contribution,
    // output exactly the bias) and must never turn it into an Err that
    // could kill an actor thread mid-collection. (The old path got the
    // bias-only result implicitly via from_range's delta=1.0 fallback
    // behind a fallible `?`; this pins the behavior explicitly so a
    // from_range contract change can't regress it.)
    let mut p = mlp_params(&[4, 8, 3], 77);
    // Zero first-layer weights AND bias: layer 0's post-relu output is
    // exactly zero for every input, so layer 1 always sees the
    // degenerate row.
    p.tensors[0].data_mut().fill(0.0);
    p.tensors[1].data_mut().fill(0.0);
    let b1 = p.tensors[3].data().to_vec();

    let mut q = EngineInt8::from_params(&p).unwrap();
    let x = [0.3f32, -0.7, 0.1, 0.9];
    let mut y = vec![0.0f32; 3];
    q.forward(&x, &mut y).expect("degenerate range must not fail");
    assert_eq!(y.as_slice(), b1.as_slice(), "zero contribution => exactly the bias");

    // Batched path: one normal-looking input row plus an all-zero input
    // row (degenerate from layer 0 already); both must agree with the
    // scalar result bit-for-bit.
    let xs = [0.3f32, -0.7, 0.1, 0.9, 0.0, 0.0, 0.0, 0.0];
    let mut yb = vec![0.0f32; 6];
    q.forward_batch(&xs, 2, &mut yb).expect("degenerate batch must not fail");
    assert_eq!(&yb[..3], y.as_slice());
    assert_eq!(&yb[3..], b1.as_slice());

    // An all-zero observation into an otherwise normal net must also
    // survive both paths (this is the realistic env-reset case).
    let p2 = mlp_params(&[4, 8, 3], 78);
    let mut q2 = EngineInt8::from_params(&p2).unwrap();
    let zero = [0.0f32; 4];
    let mut y2 = vec![0.0f32; 3];
    q2.forward(&zero, &mut y2).expect("all-zero obs must not fail");
    let mut y2b = vec![0.0f32; 3];
    q2.forward_batch(&zero, 1, &mut y2b).unwrap();
    assert_eq!(y2, y2b);
}

/// Scalar fake-quant reference for the bitwidth-generic engine, built
/// from the *public* QParams API only (no engine internals): weights on
/// the centered `bits`-bit grid via `quantize_code`, activations
/// dynamically quantized at 8 bits per row, i32 accumulation, and the
/// engine's exact float epilogue (`(a_delta * w_delta) * acc + b`).
/// Because the integer sums are exact and the float expressions match,
/// the packed engine must reproduce this bit for bit — the property
/// that lets sub-8-bit experiment rows replace `fake_quant_*`
/// simulation with real packed kernels.
fn fake_quant_reference(p: &ParamSet, xs: &[f32], batch: usize, bits: u32) -> Vec<f32> {
    let n_layers = p.tensors.len() / 2;
    let in_dim = p.tensors[0].shape()[0];
    let mut act: Vec<f32> = xs[..batch * in_dim].to_vec();
    let mut n = in_dim;
    for li in 0..n_layers {
        let w = &p.tensors[2 * li];
        let b = &p.tensors[2 * li + 1];
        let m = w.shape()[1];
        let last = li + 1 == n_layers;
        let w_qp = QParams::from_range(w.min(), w.max(), bits).unwrap();
        let mut next = vec![0.0f32; batch * m];
        for r in 0..batch {
            let a = &act[r * n..(r + 1) * n];
            let amin = a.iter().copied().fold(f32::INFINITY, f32::min);
            let amax = a.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let (scale, qa): (f32, Vec<i32>) = if amin == amax && amin == 0.0 {
                (0.0, vec![0; n])
            } else {
                let a_qp = QParams::from_range(amin, amax, 8).unwrap();
                let za = a_qp.zero_point;
                (
                    a_qp.delta * w_qp.delta,
                    a.iter().map(|&v| (a_qp.quantize(v) - za) as i32).collect(),
                )
            };
            for c in 0..m {
                let mut acc = 0i32;
                for (i, &q) in qa.iter().enumerate() {
                    acc += q * w_qp.quantize_code(w.data()[i * m + c], bits) as i32;
                }
                let mut y = scale * acc as f32 + b.data()[c];
                if !last && y < 0.0 {
                    y = 0.0;
                }
                next[r * m + c] = y;
            }
        }
        act = next;
        n = m;
    }
    act
}

#[test]
fn int4_packed_gemm_bit_exact_with_scalar_fake_quant_reference() {
    // The ISSUE-4 acceptance property: the packed int4 engine (nibble
    // storage, panel unpacking inside the tile loop, hoisted zero-point
    // correction) is bit-identical per row to the scalar fake-quant
    // reference built from public QParams math — across random shapes,
    // odd widths (rows start mid-byte), and batch sizes that force
    // scratch-arena regrowth.
    let mut rng = Pcg32::new(701, 1);
    let shapes: [&[usize]; 5] = [
        &[4, 16, 2],
        &[7, 33, 19, 3],
        &[12, 64, 64, 5],
        &[5, 21, 2],
        &[128, 512, 512, 25],
    ];
    for (case, dims) in shapes.iter().enumerate() {
        let p = mlp_params(dims, 7000 + case as u64);
        let mut eng = EngineQuant::from_params(&p, 4).unwrap();
        let din = dims[0];
        let dout = *dims.last().unwrap();
        let batch_sizes: &[usize] = if din >= 128 { &[1, 64] } else { &[1, 3, 7, 64] };
        for &batch in batch_sizes {
            let xs: Vec<f32> =
                (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
            let want = fake_quant_reference(&p, &xs, batch, 4);
            let mut got = vec![0.0f32; batch * dout];
            eng.forward_batch(&xs, batch, &mut got).unwrap();
            for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!(
                    a == b,
                    "case {case} batch {batch} element {k}: reference {a} ({:#x}) vs packed {b} ({:#x})",
                    a.to_bits(),
                    b.to_bits()
                );
            }
            // and the scalar GEMV path agrees too
            let mut scalar = vec![0.0f32; dout];
            for r in 0..batch {
                eng.forward(&xs[r * din..(r + 1) * din], &mut scalar).unwrap();
                for (k, (a, b)) in
                    want[r * dout..(r + 1) * dout].iter().zip(&scalar).enumerate()
                {
                    assert!(a == b, "case {case} scalar row {r} element {k}: {a} vs {b}");
                }
            }
        }
    }
}

#[test]
fn every_engine_bitwidth_matches_the_fake_quant_reference() {
    // The same bit-exactness property at every engine-supported width:
    // 2 runs crumb-packed, 3..=4 nibble-packed, 5..=8 byte-stored — one
    // kernel for all.
    let mut rng = Pcg32::new(702, 1);
    for bits in 2u32..=8 {
        let p = mlp_params(&[9, 40, 17, 4], 7100 + bits as u64);
        let mut eng = EngineQuant::from_params(&p, bits).unwrap();
        let batch = 6;
        let xs: Vec<f32> = (0..batch * 9).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let want = fake_quant_reference(&p, &xs, batch, bits);
        let mut got = vec![0.0f32; batch * 4];
        eng.forward_batch(&xs, batch, &mut got).unwrap();
        for (k, (a, b)) in want.iter().zip(&got).enumerate() {
            assert!(a == b, "bits {bits} element {k}: reference {a} vs engine {b}");
        }
    }
}

#[test]
fn swar_prepacked_gemm_pins_rowmajor_and_fake_quant_at_every_width() {
    // The ISSUE-5 acceptance property: for widths 2..=8, across random
    // and odd shapes (packed rows straddling bytes, multi-block output
    // widths, tail input rows), the SWAR-prepacked panel GEMM, the
    // scalar GEMV, the PR-4 row-major kernel, and the scalar fake-quant
    // reference built from public QParams math are all bit-identical —
    // the panel repack and bulk unpack are pure layout moves.
    let mut rng = Pcg32::new(801, 1);
    let shapes: [&[usize]; 4] = [
        &[4, 16, 2],
        &[7, 33, 19, 3],
        &[9, 140, 6],
        &[5, 21, 2],
    ];
    for bits in 2u32..=8 {
        for (case, dims) in shapes.iter().enumerate() {
            let p = mlp_params(dims, 8000 + bits as u64 * 10 + case as u64);
            let mut panel_eng = EngineQuant::from_params(&p, bits).unwrap();
            let mut rm_eng = EngineQuant::from_params_cfg(
                &p,
                bits,
                EngineConfig { kernel: KernelKind::RowMajor, ..EngineConfig::default() },
            )
            .unwrap();
            let din = dims[0];
            let dout = *dims.last().unwrap();
            for &batch in &[1usize, 3, 7] {
                let xs: Vec<f32> =
                    (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
                let want = fake_quant_reference(&p, &xs, batch, bits);
                let mut got = vec![0.0f32; batch * dout];
                panel_eng.forward_batch(&xs, batch, &mut got).unwrap();
                assert_eq!(want, got, "panel batched, bits {bits} case {case} batch {batch}");
                rm_eng.forward_batch(&xs, batch, &mut got).unwrap();
                assert_eq!(want, got, "rowmajor batched, bits {bits} case {case} batch {batch}");
                let mut scalar = vec![0.0f32; dout];
                for r in 0..batch {
                    panel_eng.forward(&xs[r * din..(r + 1) * din], &mut scalar).unwrap();
                    assert_eq!(
                        &want[r * dout..(r + 1) * dout],
                        scalar.as_slice(),
                        "panel GEMV, bits {bits} case {case} row {r}"
                    );
                }
            }
        }
    }
}

#[test]
fn swar_prepacked_kernels_survive_degenerate_rows() {
    // Degenerate activation rows (layer dead after relu / all-zero
    // observation) through the prepacked kernel, packed (int2/int4) and
    // byte-stored: output exactly the bias, bit-equal to the reference,
    // never an Err — same contract the row-major kernel pins above.
    for bits in [2u32, 4, 8] {
        let mut p = mlp_params(&[4, 8, 3], 90 + bits as u64);
        p.tensors[0].data_mut().fill(0.0);
        p.tensors[1].data_mut().fill(0.0);
        let b1 = p.tensors[3].data().to_vec();
        let mut eng = EngineQuant::from_params(&p, bits).unwrap();
        let xs = [0.3f32, -0.7, 0.1, 0.9, 0.0, 0.0, 0.0, 0.0];
        let want = fake_quant_reference(&p, &xs, 2, bits);
        let mut got = vec![0.0f32; 6];
        eng.forward_batch(&xs, 2, &mut got).expect("degenerate batch must not fail");
        assert_eq!(want, got, "bits {bits}");
        assert_eq!(&got[..3], b1.as_slice(), "zero contribution => exactly the bias");
        let mut y = vec![0.0f32; 3];
        eng.forward(&xs[4..8], &mut y).expect("all-zero obs must not fail");
        assert_eq!(y.as_slice(), b1.as_slice(), "bits {bits} scalar path");
    }
}

#[test]
fn thread_counts_are_bit_invariant_for_forward_batch() {
    // The intra-op parallel path submits disjoint output-column blocks
    // to the shared persistent worker pool and runs the same per-element
    // arithmetic, so threads in {1, 2, 4} must produce bit-identical
    // forward_batch output at EVERY native width 2..=8 — packed and
    // byte-stored, odd multi-block shapes, batches that don't divide the
    // 4-row microkernel.
    let mut rng = Pcg32::new(802, 1);
    for bits in 2u32..=8 {
        for (case, dims) in [&[12usize, 300, 140, 9][..], &[6, 129, 5]].iter().enumerate() {
            let p = mlp_params(dims, 8800 + bits as u64 * 10 + case as u64);
            let din = dims[0];
            let dout = *dims.last().unwrap();
            for &batch in &[1usize, 5, 8] {
                let xs: Vec<f32> =
                    (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
                let mut want = vec![0.0f32; batch * dout];
                EngineQuant::from_params_cfg(&p, bits, EngineConfig::with_threads(1))
                    .unwrap()
                    .forward_batch(&xs, batch, &mut want)
                    .unwrap();
                for threads in [2usize, 4] {
                    let mut eng =
                        EngineQuant::from_params_cfg(&p, bits, EngineConfig::with_threads(threads))
                            .unwrap();
                    let mut got = vec![0.0f32; batch * dout];
                    eng.forward_batch(&xs, batch, &mut got).unwrap();
                    assert_eq!(
                        want, got,
                        "bits {bits} case {case} batch {batch} threads {threads}"
                    );
                    // Live resizes mid-run (the Engine::set_threads
                    // route) must keep the invariant in both directions:
                    // down to the sequential path, then back up to a
                    // count the engine has not used before.
                    eng.set_threads(1);
                    eng.forward_batch(&xs, batch, &mut got).unwrap();
                    assert_eq!(want, got, "set_threads(1) after {threads}");
                    eng.set_threads(threads + 1);
                    eng.forward_batch(&xs, batch, &mut got).unwrap();
                    assert_eq!(want, got, "set_threads({}) resize", threads + 1);
                }
            }
        }
    }
}

#[test]
fn int8_engine_unchanged_by_the_generic_refactor() {
    // EngineInt8 is now a thin instantiation of EngineQuant at bits 8;
    // its outputs must be exactly what the PR-3 standalone kernel
    // produced. The fake-quant reference above *is* that kernel's
    // arithmetic (same quantizer, same i32 sums, same epilogue), so
    // pinning EngineInt8 == reference == EngineQuant@8 pins the PR-3
    // contract without keeping a second implementation around.
    let mut rng = Pcg32::new(703, 1);
    for (case, dims) in [&[4usize, 16, 2][..], &[12, 64, 32, 25], &[7, 33, 19, 3]]
        .iter()
        .enumerate()
    {
        let p = mlp_params(dims, 7200 + case as u64);
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let mut q8 = EngineQuant::from_params(&p, 8).unwrap();
        let din = dims[0];
        let dout = *dims.last().unwrap();
        let batch = 5;
        let xs: Vec<f32> = (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
        let want = fake_quant_reference(&p, &xs, batch, 8);
        let mut a = vec![0.0f32; batch * dout];
        let mut b = vec![0.0f32; batch * dout];
        i8e.forward_batch(&xs, batch, &mut a).unwrap();
        q8.forward_batch(&xs, batch, &mut b).unwrap();
        assert_eq!(a, b, "case {case}: thin wrapper vs generic engine");
        for (k, (w, g)) in want.iter().zip(&a).enumerate() {
            assert!(w == g, "case {case} element {k}: reference {w} vs EngineInt8 {g}");
        }
    }
}

#[test]
fn int4_argmax_agreement_stays_usable() {
    // 4-bit weights are coarse, but the deployment criterion (picking
    // the same action as fp32) must still hold on a clear majority of
    // cartpole-scale observations — the property that makes int4 actors
    // worth sweeping at all.
    let mut agree = 0usize;
    let mut trials = 0usize;
    for seed in [5u64, 31, 59] {
        let p = mlp_params(&[4, 64, 64, 2], seed);
        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i4e = EngineInt4::from_params(&p).unwrap();
        let mut rng = Pcg32::new(seed ^ 0x5A, 9);
        for _ in 0..300 {
            let x = [
                rng.uniform_range(-2.4, 2.4),
                rng.uniform_range(-3.0, 3.0),
                rng.uniform_range(-0.21, 0.21),
                rng.uniform_range(-3.0, 3.0),
            ];
            let mut yf = vec![0.0f32; 2];
            let mut yq = vec![0.0f32; 2];
            f32e.forward(&x, &mut yf);
            i4e.forward(&x, &mut yq).unwrap();
            trials += 1;
            if argmax(&yf) == argmax(&yq) {
                agree += 1;
            }
        }
    }
    assert!(
        agree * 100 >= trials * 75,
        "int4 argmax agreement {agree}/{trials} below 75%"
    );
}

#[test]
fn snapshot_rebuilt_engines_keep_bit_parity_at_every_width() {
    // The distribution guarantee feeding the same parity matrix: an
    // engine serialized into a snapshot artifact (the deployment
    // representation — packed codes + QParams, or raw fp32) and rebuilt
    // from the blob must be bit-identical to the source on both forward
    // paths, and the quantized widths must still match the fake-quant
    // reference — i.e. shipping the policy over the wire adds exactly
    // zero numeric drift.
    let mut rng = Pcg32::new(901, 1);
    let dims: &[usize] = &[7, 33, 19, 3];
    let p = mlp_params(dims, 9100);
    let (din, dout) = (dims[0], *dims.last().unwrap());
    let batch = 6;
    let xs: Vec<f32> = (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();

    // fp32: blob round trip reproduces the scalar-path bits.
    let mut f32e = EngineF32::from_params(&p).unwrap();
    let blob = Artifact::from_engine_f32(&f32e, 1).to_bytes();
    let mut rebuilt = Artifact::from_bytes(&blob)
        .unwrap()
        .build_engine(EngineConfig::default())
        .unwrap();
    let mut want = vec![0.0f32; batch * dout];
    f32e.forward_batch(&xs, batch, &mut want).unwrap();
    let mut got = vec![0.0f32; batch * dout];
    rebuilt.forward_batch(&xs, batch, &mut got).unwrap();
    assert_eq!(want, got, "fp32 snapshot round trip");

    // Every packed width: source engine, rebuilt engine, and the
    // fake-quant reference all agree bit for bit.
    for bits in 2u32..=8 {
        let mut src = EngineQuant::from_params(&p, bits).unwrap();
        let blob = Artifact::from_engine_quant(&src, bits as u64).to_bytes();
        let mut rebuilt = Artifact::from_bytes(&blob)
            .unwrap()
            .build_engine(EngineConfig::default())
            .unwrap();
        let reference = fake_quant_reference(&p, &xs, batch, bits);
        src.forward_batch(&xs, batch, &mut want).unwrap();
        rebuilt.forward_batch(&xs, batch, &mut got).unwrap();
        assert_eq!(want, got, "bits {bits}: source vs snapshot-rebuilt");
        assert_eq!(reference, got, "bits {bits}: fake-quant reference vs rebuilt");
        // scalar path too
        let mut y_src = vec![0.0f32; dout];
        let mut y_reb = vec![0.0f32; dout];
        for r in 0..batch {
            let x = &xs[r * din..(r + 1) * din];
            src.forward(x, &mut y_src).unwrap();
            rebuilt.forward(x, &mut y_reb).unwrap();
            assert_eq!(y_src, y_reb, "bits {bits} scalar row {r}");
        }
    }
}

/// Scalar sign-arithmetic reference for the bitplane engines, built
/// from the public API only: weights through `binarize`/`ternarize`
/// (the exact codec the engine packs from), activations binarized
/// around their mean via `act_bitplane_params` (bit set iff `a_i < mu`,
/// i.e. code -1, matching `pack_act_signs`), plain i32 code products in
/// place of the XNOR-popcount identity, and the engine's own
/// `bitplane_out` epilogue. The integer sums are exact and the float
/// expression is shared, so the packed kernels must reproduce this bit
/// for bit — the XNOR trick (`acc = n_eff - 2*popcount`) is pure
/// arithmetic rewriting, not an approximation.
fn bitplane_reference(p: &ParamSet, xs: &[f32], batch: usize, precision: Precision) -> Vec<f32> {
    let n_layers = p.tensors.len() / 2;
    let in_dim = p.tensors[0].shape()[0];
    let mut act: Vec<f32> = xs[..batch * in_dim].to_vec();
    let mut n = in_dim;
    for li in 0..n_layers {
        let w = &p.tensors[2 * li];
        let b = &p.tensors[2 * li + 1];
        let m = w.shape()[1];
        let relu = li + 1 < n_layers;
        let (codes, alpha_w) = match precision {
            Precision::Ternary => ternarize(w.data()),
            _ => binarize(w.data()),
        };
        let mut col_sums = vec![0i32; m];
        for r in 0..n {
            for c in 0..m {
                col_sums[c] += codes[r * m + c] as i32;
            }
        }
        let mut next = vec![0.0f32; batch * m];
        for r in 0..batch {
            let a = &act[r * n..(r + 1) * n];
            let amin = a.iter().copied().fold(f32::INFINITY, f32::min);
            let amax = a.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let degenerate = amin == amax && amin == 0.0;
            let (s1, s2, qa): (f32, f32, Vec<i32>) = if degenerate {
                (0.0, 0.0, vec![0; n])
            } else {
                let (mu, alpha) = act_bitplane_params(a);
                (
                    alpha_w * alpha,
                    alpha_w * mu,
                    a.iter().map(|&v| if v < mu { -1 } else { 1 }).collect(),
                )
            };
            for c in 0..m {
                let mut acc = 0i32;
                if !degenerate {
                    for (i, &q) in qa.iter().enumerate() {
                        acc += q * codes[i * m + c] as i32;
                    }
                }
                next[r * m + c] = bitplane_out(s1, s2, acc, col_sums[c], b.data()[c], relu);
            }
        }
        act = next;
        n = m;
    }
    act
}

#[test]
fn xnor_bitplane_gemm_bit_exact_with_scalar_sign_reference() {
    // The PR-9 acceptance property: the int1 and ternary bitplane
    // engines (column-major sign/mask planes, 64 weights per
    // xor+count_ones) are bit-identical to the scalar sign-arithmetic
    // reference across random shapes, odd widths (input rows straddling
    // the 64-bit plane words, tail chunks), multi-block output widths,
    // and batch sizes that force scratch-arena regrowth — on both the
    // batched GEMM and the scalar GEMV paths.
    let mut rng = Pcg32::new(1001, 1);
    let shapes: [&[usize]; 5] = [
        &[4, 16, 2],
        &[7, 33, 19, 3],
        &[12, 130, 70, 5],
        &[9, 200, 6],
        &[128, 512, 512, 25],
    ];
    for precision in [Precision::Int(1), Precision::Ternary] {
        for (case, dims) in shapes.iter().enumerate() {
            let p = mlp_params(dims, 9500 + case as u64);
            let mut eng =
                EngineQuant::from_params_prec(&p, precision, EngineConfig::default()).unwrap();
            let din = dims[0];
            let dout = *dims.last().unwrap();
            let batch_sizes: &[usize] = if din >= 128 { &[1, 64] } else { &[1, 3, 7, 64] };
            for &batch in batch_sizes {
                let xs: Vec<f32> =
                    (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
                let want = bitplane_reference(&p, &xs, batch, precision);
                let mut got = vec![0.0f32; batch * dout];
                eng.forward_batch(&xs, batch, &mut got).unwrap();
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert!(
                        a == b,
                        "{} case {case} batch {batch} element {k}: reference {a} ({:#x}) vs bitplane {b} ({:#x})",
                        precision.label(),
                        a.to_bits(),
                        b.to_bits()
                    );
                }
                let mut scalar = vec![0.0f32; dout];
                for r in 0..batch {
                    eng.forward(&xs[r * din..(r + 1) * din], &mut scalar).unwrap();
                    assert_eq!(
                        &want[r * dout..(r + 1) * dout],
                        scalar.as_slice(),
                        "{} case {case} GEMV row {r}",
                        precision.label()
                    );
                }
            }
        }
    }
}

#[test]
fn bitplane_kernels_survive_degenerate_rows() {
    // Same benign-skip contract the affine kernels pin: an all-zero
    // activation row (dead layer after relu, env-reset observation) has
    // no sign information to binarize — both scales vanish and the
    // output is exactly the bias, never an Err. Checked against the
    // reference too, so the degenerate branch stays on the shared path.
    for precision in [Precision::Int(1), Precision::Ternary] {
        let mut p = mlp_params(&[4, 8, 3], 96);
        p.tensors[0].data_mut().fill(0.0);
        p.tensors[1].data_mut().fill(0.0);
        let b1 = p.tensors[3].data().to_vec();
        let mut eng =
            EngineQuant::from_params_prec(&p, precision, EngineConfig::default()).unwrap();
        let xs = [0.3f32, -0.7, 0.1, 0.9, 0.0, 0.0, 0.0, 0.0];
        let want = bitplane_reference(&p, &xs, 2, precision);
        let mut got = vec![0.0f32; 6];
        eng.forward_batch(&xs, 2, &mut got).expect("degenerate batch must not fail");
        assert_eq!(want, got, "{}", precision.label());
        assert_eq!(&got[..3], b1.as_slice(), "zero contribution => exactly the bias");
        let mut y = vec![0.0f32; 3];
        eng.forward(&xs[4..8], &mut y).expect("all-zero obs must not fail");
        assert_eq!(y.as_slice(), b1.as_slice(), "{} scalar path", precision.label());
    }
}

#[test]
fn bitplane_thread_counts_are_bit_invariant() {
    // The bitplane GEMM threads over disjoint output-column blocks on
    // the shared persistent pool, same as the affine kernels — so
    // threads in {1, 2, 4} (and live set_threads resizes) must produce
    // bit-identical forward_batch output for int1 AND ternary, on
    // shapes wide enough to actually split into multiple blocks.
    let mut rng = Pcg32::new(1002, 1);
    for precision in [Precision::Int(1), Precision::Ternary] {
        for (case, dims) in [&[12usize, 300, 140, 9][..], &[6, 129, 5]].iter().enumerate() {
            let p = mlp_params(dims, 9600 + case as u64);
            let din = dims[0];
            let dout = *dims.last().unwrap();
            for &batch in &[1usize, 5, 8] {
                let xs: Vec<f32> =
                    (0..batch * din).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
                let mut want = vec![0.0f32; batch * dout];
                EngineQuant::from_params_prec(&p, precision, EngineConfig::with_threads(1))
                    .unwrap()
                    .forward_batch(&xs, batch, &mut want)
                    .unwrap();
                assert_eq!(
                    want,
                    bitplane_reference(&p, &xs, batch, precision),
                    "{} case {case} batch {batch}: single-thread vs reference",
                    precision.label()
                );
                for threads in [2usize, 4] {
                    let mut eng = EngineQuant::from_params_prec(
                        &p,
                        precision,
                        EngineConfig::with_threads(threads),
                    )
                    .unwrap();
                    let mut got = vec![0.0f32; batch * dout];
                    eng.forward_batch(&xs, batch, &mut got).unwrap();
                    assert_eq!(
                        want, got,
                        "{} case {case} batch {batch} threads {threads}",
                        precision.label()
                    );
                    eng.set_threads(1);
                    eng.forward_batch(&xs, batch, &mut got).unwrap();
                    assert_eq!(want, got, "set_threads(1) after {threads}");
                    eng.set_threads(threads + 1);
                    eng.forward_batch(&xs, batch, &mut got).unwrap();
                    assert_eq!(want, got, "set_threads({}) resize", threads + 1);
                }
            }
        }
    }
}

#[test]
fn parity_holds_for_narrow_and_wide_towers() {
    // Shape sweep: the parity property is architecture-independent.
    let mut rng = Pcg32::new(305, 1);
    for dims in [vec![4, 16, 2], vec![12, 128, 64, 5], vec![6, 32, 32, 32, 3]] {
        let p = mlp_params(&dims, 4242);
        let mut f32e = EngineF32::from_params(&p).unwrap();
        let mut i8e = EngineInt8::from_params(&p).unwrap();
        let dout = *dims.last().unwrap();
        let din = dims[0];
        let mut agree = 0usize;
        let trials = 100usize;
        for _ in 0..trials {
            let x: Vec<f32> = (0..din).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
            let mut yf = vec![0.0f32; dout];
            let mut yq = vec![0.0f32; dout];
            f32e.forward(&x, &mut yf);
            i8e.forward(&x, &mut yq).unwrap();
            if argmax(&yf) == argmax(&yq) {
                agree += 1;
            }
        }
        assert!(agree * 10 >= trials * 9, "dims {dims:?}: agreement {agree}/{trials}");
    }
}

//! Chaos property suite for the crash-safe ActorQ stack: a seeded run
//! with scripted faults (actor kill mid-run, dropped + failed hub
//! publishes, flaky client connects) must reach the same step budget
//! and the **bit-identical** final engine as the fault-free run at the
//! same seed — at fp32 and every packed width 2..=8. Same bar for a
//! learner killed mid-run and resumed from its QCKP checkpoint. And a
//! checkpoint blob must reject *every* single-byte corruption and
//! *every* truncation as a typed error before any state is restored.
//!
//! The learner is the stub train program also used by `exp faults`:
//! parameter evolution is a pure function of (train count, learner RNG
//! stream), and the pacer owes exactly `(total - warmup) / train_freq`
//! trains at equal env-step budget — so any divergence is a real
//! recovery bug, not scheduling noise.

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Duration;

use quarl::actorq::{
    ActorQConfig, Checkpoint, CheckpointPolicy, CheckpointState, HarnessConfig, LearnerHarness,
    ParamBroadcast, Precision, ReturnLog,
};
use quarl::algos::common::EpsSchedule;
use quarl::faults::FaultPlan;
use quarl::inference::Engine;
use quarl::rng::Pcg32;
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;
use quarl::snapshot::{ClientConfig, SnapshotClient, SnapshotError, SnapshotHub, SnapshotServer};

const DIMS: [usize; 3] = [4, 16, 2];
const TOTAL_STEPS: usize = 260;
const WARMUP: usize = 100;
const TRAIN_FREQ: usize = 2;
const SEED: u64 = 77;

fn init_params(seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..DIMS.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![DIMS[i], DIMS[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![DIMS[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 47);
    ParamSet::init(&specs, &mut rng)
}

fn exploration() -> quarl::actorq::Exploration {
    quarl::actorq::Exploration::EpsGreedy {
        schedule: EpsSchedule { start: 0.05, end: 0.05, fraction: 1.0 },
        horizon: 1,
    }
}

fn all_precisions() -> Vec<Precision> {
    let mut ps = vec![Precision::Fp32];
    ps.extend((2..=8).map(Precision::Int));
    ps
}

/// Run the stub learner to completion and return the probe signature of
/// the final published engine (raw logit bits at seeded inputs).
fn run_and_probe(
    precision: Precision,
    faults: Option<Arc<FaultPlan>>,
    ckpt: Option<CheckpointPolicy>,
    resume_from: Option<&Checkpoint>,
    crash_after: Option<usize>,
    hub: Option<Arc<SnapshotHub>>,
) -> Result<(Vec<u32>, usize, usize), quarl::Error> {
    let (params, rng) = match resume_from {
        Some(c) => (c.params.clone(), c.rng()),
        None => (init_params(SEED), Pcg32::new(SEED, 4242)),
    };
    let mut acfg = ActorQConfig::new(2).with_precision(precision);
    acfg.restart_backoff = Duration::from_millis(2);
    let hcfg = HarnessConfig {
        env_id: "cartpole",
        seed: SEED,
        total_steps: TOTAL_STEPS,
        warmup: WARMUP,
        train_freq: TRAIN_FREQ,
        log_every: 0,
        exploration: exploration(),
        returns: ReturnLog::TailMean,
        acfg: &acfg,
        faults,
        ckpt: ckpt.clone(),
        resume: resume_from.map(|c| c.resume_point()),
    };
    let harness = LearnerHarness::spawn(&params, &hcfg)?;
    if let Some(hub) = hub {
        harness.broadcast.attach_hub(hub)?;
    }
    let broadcast = harness.broadcast.clone();
    let pstate = RefCell::new(params);
    let rstate = RefCell::new(rng);
    let mut calls = 0usize;
    let train = |_step: usize, publish: bool| -> Result<Option<f32>, quarl::Error> {
        if crash_after.is_some_and(|limit| calls >= limit) {
            return Err(quarl::Error::Experiment("injected learner crash".into()));
        }
        calls += 1;
        let mut p = pstate.borrow_mut();
        let mut r = rstate.borrow_mut();
        for t in p.tensors.iter_mut() {
            for v in t.data_mut() {
                *v += 0.003 * r.normal();
            }
        }
        if publish {
            broadcast.publish(&p)?;
        }
        Ok(Some(0.0))
    };
    let mut state_fn = || CheckpointState {
        params: pstate.borrow().clone(),
        rng: rstate.borrow().state_parts(),
    };
    let state: Option<&mut dyn FnMut() -> CheckpointState> =
        if ckpt.is_some() { Some(&mut state_fn) } else { None };
    let log = harness.run_ckpt(|_t| {}, train, state)?;
    let sig = probe(&broadcast);
    Ok((sig, log.train_steps, log.actor_restarts))
}

fn probe(broadcast: &ParamBroadcast) -> Vec<u32> {
    let mut eng = broadcast.latest().engine.clone();
    let mut rng = Pcg32::new(SEED, 99);
    let mut x = vec![0.0f32; DIMS[0]];
    let mut y = vec![0.0f32; DIMS[2]];
    let mut sig = Vec::new();
    for _ in 0..8 {
        for v in x.iter_mut() {
            *v = rng.uniform_range(-1.0, 1.0);
        }
        eng.forward(&x, &mut y).unwrap();
        sig.extend(y.iter().map(|v| v.to_bits()));
    }
    sig
}

#[test]
fn faulted_run_matches_clean_run_bit_for_bit_at_every_width() {
    for precision in all_precisions() {
        let (clean_sig, clean_trains, clean_restarts) =
            run_and_probe(precision, None, None, None, None, None).unwrap();
        assert_eq!(clean_restarts, 0);
        assert_eq!(clean_trains, (TOTAL_STEPS - WARMUP) / TRAIN_FREQ);

        // Kill actor 0 mid-run, drop one hub publish, fail another on
        // the wire, and fail the client's first two connects.
        let plan = Arc::new(
            FaultPlan::new(SEED)
                .kill_actor(0, 40)
                .drop_publish(2)
                .fail_publish(3)
                .fail_connect(1)
                .fail_connect(2),
        );
        let hub = Arc::new(SnapshotHub::new());
        let server = SnapshotServer::bind("127.0.0.1:0", Arc::clone(&hub)).unwrap();
        let (faulted_sig, faulted_trains, restarts) = run_and_probe(
            precision,
            Some(plan.clone()),
            None,
            None,
            None,
            Some(hub),
        )
        .unwrap();
        let label = precision.label();
        assert_eq!(restarts, 1, "{label}: the kill must be absorbed by a respawn");
        assert_eq!(faulted_trains, clean_trains, "{label}: equal step budget");
        assert_eq!(faulted_sig, clean_sig, "{label}: recovery must be bit-exact");

        // The flaky-transport leg: two scripted connect failures are
        // retried away and the fetched engine matches the broadcast.
        let client = SnapshotClient::with_config(
            server.addr(),
            ClientConfig {
                backoff: Duration::from_millis(1),
                jitter_seed: SEED,
                faults: Some(plan.clone()),
                ..ClientConfig::default()
            },
        );
        let art = client.fetch().unwrap();
        assert!(client.retries() >= 2, "{label}: both connect faults retried");
        let mut remote = art.build_engine(Default::default()).unwrap();
        let mut rng = Pcg32::new(SEED, 99);
        let mut x = vec![0.0f32; DIMS[0]];
        let mut y = vec![0.0f32; DIMS[2]];
        let mut wire_sig = Vec::new();
        for _ in 0..8 {
            for v in x.iter_mut() {
                *v = rng.uniform_range(-1.0, 1.0);
            }
            remote.forward(&x, &mut y).unwrap();
            wire_sig.extend(y.iter().map(|v| v.to_bits()));
        }
        assert_eq!(wire_sig, clean_sig, "{label}: wire copy must match too");
    }
}

#[test]
fn killed_learner_resumes_from_checkpoint_to_the_same_engine() {
    let dir = std::env::temp_dir().join("quarl_faults_chaos_resume");
    let _ = std::fs::remove_dir_all(&dir);
    for precision in all_precisions() {
        let label = precision.label();
        let (clean_sig, clean_trains, _) =
            run_and_probe(precision, None, None, None, None, None).unwrap();

        let path = dir.join(format!("{label}.qckp"));
        let policy = CheckpointPolicy { path: path.clone(), every_trains: 10 };
        let crash_at = clean_trains * 3 / 5;
        let err = run_and_probe(precision, None, Some(policy), None, Some(crash_at), None)
            .expect_err("the scripted crash must abort the run");
        assert!(err.to_string().contains("injected learner crash"), "{label}: {err}");

        let ckpt = Checkpoint::read_file(&path).unwrap();
        assert_eq!(ckpt.train_steps as usize, crash_at - crash_at % 10, "{label}");
        let (resumed_sig, resumed_trains, _) =
            run_and_probe(precision, None, None, Some(&ckpt), None, None).unwrap();
        assert_eq!(resumed_trains, clean_trains, "{label}: resumed run pays the remainder");
        assert_eq!(resumed_sig, clean_sig, "{label}: resume must be bit-exact");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_corrupted_or_truncated_checkpoint_byte_is_a_typed_error() {
    let params = init_params(9);
    let mut rng = Pcg32::new(9, 4242);
    for _ in 0..13 {
        rng.next_u32();
    }
    let ckpt = Checkpoint {
        train_steps: 42,
        env_steps: 184,
        broadcasts: 4,
        version: 4,
        replay_pushed: 203,
        rng: rng.state_parts(),
        params,
    };
    let blob = ckpt.to_bytes();
    assert_eq!(Checkpoint::from_bytes(&blob).unwrap(), ckpt, "pristine blob must verify");

    for i in 0..blob.len() {
        let mut bad = blob.clone();
        bad[i] ^= 0xFF;
        let err = Checkpoint::from_bytes(&bad)
            .expect_err(&format!("flipped byte {i} must be detected"));
        // Every rejection is a typed SnapshotError, surfaced before any
        // state is restored.
        let _: &SnapshotError = &err;
    }
    for len in 0..blob.len() {
        Checkpoint::from_bytes(&blob[..len])
            .expect_err(&format!("truncation to {len} bytes must be detected"));
    }
}

//! Chaos property suite for the crash-safe ActorQ stack: a seeded run
//! with scripted faults (actor kill mid-run, dropped + failed hub
//! publishes, severed partition windows, flaky client connects) must
//! reach the same step budget and the **bit-identical** final engine as
//! the fault-free run at the same seed — at fp32 and every supported
//! width (int1, ternary, int2..=int8). Same bar for a learner killed
//! mid-run and resumed from its QCKP checkpoint, for a learner *hung*
//! mid-run and restarted by the watchdog, and for resumed *prioritized
//! sampling* when the checkpoint carries a durable replay section. And
//! a checkpoint blob — with or without replay — must reject *every*
//! single-byte corruption and *every* truncation as a typed error
//! before any state is restored.
//!
//! The learner is the stub train program also used by `exp faults`:
//! parameter evolution is a pure function of (train count, learner RNG
//! stream) — plus, in the replay-coupled runs, of replay state the QCKP
//! replay section restores exactly — and the pacer owes exactly
//! `(total - warmup) / train_freq` trains at equal env-step budget — so
//! any divergence is a real recovery bug, not scheduling noise.

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Duration;

use quarl::actorq::watchdog::supervise;
use quarl::actorq::{
    ActorQConfig, Checkpoint, CheckpointPolicy, CheckpointState, HarnessConfig, Heartbeat,
    LearnerHarness, ParamBroadcast, Precision, ReplayCkpt, ReplaySection, RestartCause,
    ReturnLog, WatchdogConfig,
};
use quarl::algos::common::EpsSchedule;
use quarl::faults::{FaultKind, FaultPlan};
use quarl::inference::Engine;
use quarl::replay::{PrioritizedReplay, ReplayBuffer, Transition};
use quarl::rng::Pcg32;
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;
use quarl::snapshot::{ClientConfig, SnapshotClient, SnapshotError, SnapshotHub, SnapshotServer};

const DIMS: [usize; 3] = [4, 16, 2];
const TOTAL_STEPS: usize = 260;
const WARMUP: usize = 100;
const TRAIN_FREQ: usize = 2;
const SEED: u64 = 77;
/// Replay capacity for the replay-coupled runs — small enough that the
/// ring wraps, so checkpoints cover a wrapped buffer.
const REPLAY_CAP: usize = 64;

fn init_params(seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..DIMS.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![DIMS[i], DIMS[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![DIMS[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 47);
    ParamSet::init(&specs, &mut rng)
}

fn exploration() -> quarl::actorq::Exploration {
    quarl::actorq::Exploration::EpsGreedy {
        schedule: EpsSchedule { start: 0.05, end: 0.05, fraction: 1.0 },
        horizon: 1,
    }
}

fn all_precisions() -> Vec<Precision> {
    let mut ps = vec![Precision::Fp32, Precision::Int(1), Precision::Ternary];
    ps.extend((2..=8).map(Precision::Int));
    ps
}

/// One stub-learner run; every optional lever the suite pulls.
struct RunSpec<'a> {
    precision: Precision,
    faults: Option<Arc<FaultPlan>>,
    ckpt: Option<CheckpointPolicy>,
    resume_from: Option<&'a Checkpoint>,
    crash_after: Option<usize>,
    hub: Option<Arc<SnapshotHub>>,
    /// Watchdog heartbeat: beat once per train call and honor scripted
    /// `hang_learner` faults by parking until cancelled.
    watchdog: Option<&'a Heartbeat>,
    /// Couple the drift to a prioritized replay buffer (pushes and
    /// samples are pure functions of the *global* train index), and
    /// include the full replay section in checkpoints.
    replay: bool,
}

impl<'a> RunSpec<'a> {
    fn new(precision: Precision) -> RunSpec<'a> {
        RunSpec {
            precision,
            faults: None,
            ckpt: None,
            resume_from: None,
            crash_after: None,
            hub: None,
            watchdog: None,
            replay: false,
        }
    }
}

/// Run the stub learner to completion and return the probe signature of
/// the final published engine (raw logit bits at seeded inputs), the
/// train count, and the actor-restart count.
fn run_spec(spec: RunSpec) -> Result<(Vec<u32>, usize, usize), quarl::Error> {
    let RunSpec { precision, faults, ckpt, resume_from, crash_after, hub, watchdog, replay } =
        spec;
    let (params, rng) = match resume_from {
        Some(c) => (c.params.clone(), c.rng()),
        None => (init_params(SEED), Pcg32::new(SEED, 4242)),
    };
    let (per_init, sampler_init) = match resume_from.and_then(|c| c.replay.as_ref()) {
        Some(rs) if replay => match &rs.replay {
            ReplayCkpt::Prioritized(st) => (PrioritizedReplay::from_state(st), rs.sampler()),
            ReplayCkpt::Uniform(_) => panic!("replay-coupled run checkpoints PER"),
        },
        _ => (PrioritizedReplay::new(REPLAY_CAP, DIMS[0], 1, 0.6), Pcg32::new(SEED, 555)),
    };
    let base = resume_from.map(|c| c.train_steps as usize).unwrap_or(0);
    let mut acfg = ActorQConfig::new(2).with_precision(precision);
    acfg.restart_backoff = Duration::from_millis(2);
    let hcfg = HarnessConfig {
        env_id: "cartpole",
        seed: SEED,
        total_steps: TOTAL_STEPS,
        warmup: WARMUP,
        train_freq: TRAIN_FREQ,
        log_every: 0,
        exploration: exploration(),
        returns: ReturnLog::TailMean,
        acfg: &acfg,
        faults: faults.clone(),
        ckpt: ckpt.clone(),
        resume: resume_from.map(|c| c.resume_point()),
    };
    let harness = LearnerHarness::spawn(&params, &hcfg)?;
    if let Some(hub) = hub {
        harness.broadcast.attach_hub(hub)?;
    }
    let broadcast = harness.broadcast.clone();
    let pstate = RefCell::new(params);
    let rstate = RefCell::new(rng);
    let per = RefCell::new(per_init);
    let sampler = RefCell::new(sampler_init);
    let mut calls = 0usize;
    let train = |_step: usize, publish: bool| -> Result<Option<f32>, quarl::Error> {
        if let Some(hb) = watchdog {
            hb.beat();
        }
        let t = base + calls + 1; // 1-based global train index about to run
        if let Some(plan) = faults.as_deref() {
            if plan.learner_should_hang(t) {
                // Scripted hang: stop beating and park until the
                // watchdog cancels the attempt.
                loop {
                    match watchdog {
                        Some(hb) if hb.cancelled() => {
                            return Err(quarl::Error::Experiment(
                                "hung learner cancelled by watchdog".into(),
                            ))
                        }
                        Some(_) => std::thread::park_timeout(Duration::from_millis(1)),
                        None => {
                            return Err(quarl::Error::Experiment(
                                "scripted learner hang with no watchdog attached".into(),
                            ))
                        }
                    }
                }
            }
        }
        if crash_after.is_some_and(|limit| calls >= limit) {
            return Err(quarl::Error::Experiment("injected learner crash".into()));
        }
        calls += 1;
        let mut p = pstate.borrow_mut();
        let mut r = rstate.borrow_mut();
        let gain = if replay {
            let mut per = per.borrow_mut();
            let mut smp = sampler.borrow_mut();
            let mut t_rng =
                Pcg32::new(SEED ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15), 777);
            let obs: Vec<f32> = (0..DIMS[0]).map(|_| t_rng.uniform_range(-1.0, 1.0)).collect();
            let act = [t_rng.below_usize(DIMS[2]) as f32];
            let reward = t_rng.uniform();
            per.push(Transition { obs: &obs, action: &act, reward, next_obs: &obs, done: false });
            if per.len() >= 8 {
                let b = per.sample(4, 0.4, &mut smp);
                let errs: Vec<f32> = b.indices.iter().map(|&i| 0.05 + 0.01 * i as f32).collect();
                per.update_priorities(&b.indices, &errs);
                1.0 + 0.01 * b.weights.data().iter().sum::<f32>()
            } else {
                1.0
            }
        } else {
            1.0
        };
        for tns in p.tensors.iter_mut() {
            for v in tns.data_mut() {
                *v += 0.003 * r.normal() * gain;
            }
        }
        if publish {
            broadcast.publish(&p)?;
        }
        Ok(Some(0.0))
    };
    let mut state_fn = || CheckpointState {
        params: pstate.borrow().clone(),
        rng: rstate.borrow().state_parts(),
        replay: replay.then(|| ReplaySection {
            replay: ReplayCkpt::Prioritized(per.borrow().state()),
            sampler_rng: sampler.borrow().state_parts(),
        }),
    };
    let state: Option<&mut dyn FnMut() -> CheckpointState> =
        if ckpt.is_some() { Some(&mut state_fn) } else { None };
    let log = harness.run_ckpt(|_t| {}, train, state)?;
    let sig = probe(&broadcast);
    Ok((sig, log.train_steps, log.actor_restarts))
}

fn probe(broadcast: &ParamBroadcast) -> Vec<u32> {
    let mut eng = broadcast.latest().engine.clone();
    let mut rng = Pcg32::new(SEED, 99);
    let mut x = vec![0.0f32; DIMS[0]];
    let mut y = vec![0.0f32; DIMS[2]];
    let mut sig = Vec::new();
    for _ in 0..8 {
        for v in x.iter_mut() {
            *v = rng.uniform_range(-1.0, 1.0);
        }
        eng.forward(&x, &mut y).unwrap();
        sig.extend(y.iter().map(|v| v.to_bits()));
    }
    sig
}

#[test]
fn faulted_run_matches_clean_run_bit_for_bit_at_every_width() {
    for precision in all_precisions() {
        let (clean_sig, clean_trains, clean_restarts) =
            run_spec(RunSpec::new(precision)).unwrap();
        assert_eq!(clean_restarts, 0);
        assert_eq!(clean_trains, (TOTAL_STEPS - WARMUP) / TRAIN_FREQ);

        // Kill actor 0 mid-run, drop one hub publish, fail another on
        // the wire, and fail the client's first two connects.
        let plan = Arc::new(
            FaultPlan::new(SEED)
                .kill_actor(0, 40)
                .drop_publish(2)
                .fail_publish(3)
                .fail_connect(1)
                .fail_connect(2),
        );
        let hub = Arc::new(SnapshotHub::new());
        let server = SnapshotServer::bind("127.0.0.1:0", Arc::clone(&hub)).unwrap();
        let (faulted_sig, faulted_trains, restarts) = run_spec(RunSpec {
            faults: Some(plan.clone()),
            hub: Some(hub),
            ..RunSpec::new(precision)
        })
        .unwrap();
        let label = precision.label();
        assert_eq!(restarts, 1, "{label}: the kill must be absorbed by a respawn");
        assert_eq!(faulted_trains, clean_trains, "{label}: equal step budget");
        assert_eq!(faulted_sig, clean_sig, "{label}: recovery must be bit-exact");

        // The flaky-transport leg: two scripted connect failures are
        // retried away and the fetched engine matches the broadcast.
        let client = SnapshotClient::with_config(
            server.addr(),
            ClientConfig {
                backoff: Duration::from_millis(1),
                jitter_seed: SEED,
                faults: Some(plan.clone()),
                ..ClientConfig::default()
            },
        );
        let art = client.fetch().unwrap();
        assert!(client.retries() >= 2, "{label}: both connect faults retried");
        let mut remote = art.build_engine(Default::default()).unwrap();
        let mut rng = Pcg32::new(SEED, 99);
        let mut x = vec![0.0f32; DIMS[0]];
        let mut y = vec![0.0f32; DIMS[2]];
        let mut wire_sig = Vec::new();
        for _ in 0..8 {
            for v in x.iter_mut() {
                *v = rng.uniform_range(-1.0, 1.0);
            }
            remote.forward(&x, &mut y).unwrap();
            wire_sig.extend(y.iter().map(|v| v.to_bits()));
        }
        assert_eq!(wire_sig, clean_sig, "{label}: wire copy must match too");
    }
}

#[test]
fn killed_learner_resumes_from_checkpoint_to_the_same_engine() {
    let dir = std::env::temp_dir().join("quarl_faults_chaos_resume");
    let _ = std::fs::remove_dir_all(&dir);
    for precision in all_precisions() {
        let label = precision.label();
        let (clean_sig, clean_trains, _) = run_spec(RunSpec::new(precision)).unwrap();

        let path = dir.join(format!("{label}.qckp"));
        let policy = CheckpointPolicy { path: path.clone(), every_trains: 10 };
        let crash_at = clean_trains * 3 / 5;
        let err = run_spec(RunSpec {
            ckpt: Some(policy),
            crash_after: Some(crash_at),
            ..RunSpec::new(precision)
        })
        .expect_err("the scripted crash must abort the run");
        assert!(err.to_string().contains("injected learner crash"), "{label}: {err}");

        let ckpt = Checkpoint::read_file(&path).unwrap();
        assert_eq!(ckpt.train_steps as usize, crash_at - crash_at % 10, "{label}");
        assert!(ckpt.replay.is_none(), "{label}: non-replay runs keep lean checkpoints");
        let (resumed_sig, resumed_trains, _) = run_spec(RunSpec {
            resume_from: Some(&ckpt),
            ..RunSpec::new(precision)
        })
        .unwrap();
        assert_eq!(resumed_trains, clean_trains, "{label}: resumed run pays the remainder");
        assert_eq!(resumed_sig, clean_sig, "{label}: resume must be bit-exact");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A checkpoint carrying the durable replay section restores the buffer,
/// `SumTree` priorities, and sampler RNG so exactly that the *resumed
/// run's prioritized sampling* — which the drift is coupled to — leads
/// to the bit-identical final engine, at fp32 and the paper's sub-byte
/// widths.
#[test]
fn resumed_prioritized_sampling_is_bit_exact_at_every_width() {
    let dir = std::env::temp_dir().join("quarl_faults_chaos_replay_resume");
    let _ = std::fs::remove_dir_all(&dir);
    for precision in [
        Precision::Fp32,
        Precision::Int(1),
        Precision::Ternary,
        Precision::Int(2),
        Precision::Int(4),
        Precision::Int(8),
    ] {
        let label = precision.label();
        let (clean_sig, clean_trains, _) =
            run_spec(RunSpec { replay: true, ..RunSpec::new(precision) }).unwrap();

        let path = dir.join(format!("{label}.qckp"));
        let policy = CheckpointPolicy { path: path.clone(), every_trains: 10 };
        let crash_at = clean_trains * 3 / 5;
        run_spec(RunSpec {
            replay: true,
            ckpt: Some(policy),
            crash_after: Some(crash_at),
            ..RunSpec::new(precision)
        })
        .expect_err("the scripted crash must abort the run");

        let ckpt = Checkpoint::read_file(&path).unwrap();
        let rs = ckpt.replay.as_ref().expect("checkpoint must carry the replay section");
        assert!(!rs.is_empty(), "{label}: replay rows survived the round trip");
        assert_eq!(rs.len(), REPLAY_CAP.min(ckpt.train_steps as usize), "{label}");
        let (resumed_sig, resumed_trains, _) = run_spec(RunSpec {
            replay: true,
            resume_from: Some(&ckpt),
            ..RunSpec::new(precision)
        })
        .unwrap();
        assert_eq!(resumed_trains, clean_trains, "{label}: resumed run pays the remainder");
        assert_eq!(
            resumed_sig, clean_sig,
            "{label}: resumed prioritized sampling must be bit-exact"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A hub partition window severs every publish inside it; the window
/// heals on the next publish and the run still converges bit-identically
/// (actors ride the in-process broadcast throughout).
#[test]
fn partition_window_heals_and_converges_bit_identically() {
    let (clean_sig, clean_trains, _) = run_spec(RunSpec::new(Precision::Int(8))).unwrap();

    let plan = Arc::new(FaultPlan::new(SEED).partition(2, 4));
    let hub = Arc::new(SnapshotHub::new());
    let server = SnapshotServer::bind("127.0.0.1:0", Arc::clone(&hub)).unwrap();
    let (sig, trains, restarts) = run_spec(RunSpec {
        faults: Some(plan.clone()),
        hub: Some(hub),
        ..RunSpec::new(Precision::Int(8))
    })
    .unwrap();
    assert_eq!(restarts, 0);
    assert_eq!(trains, clean_trains, "partition must not change the train budget");
    assert_eq!(sig, clean_sig, "partitioned run must converge bit-identically");
    assert_eq!(plan.partition_windows(), 1, "the window was entered");
    assert_eq!(plan.count(FaultKind::Partition), 2, "publishes 2 and 3 were severed");

    // The hub healed: the post-window publishes landed, and the served
    // artifact hydrates the bit-identical engine.
    let client = SnapshotClient::with_config(
        server.addr(),
        ClientConfig { jitter_seed: SEED, ..ClientConfig::default() },
    );
    let art = client.fetch().unwrap();
    let mut remote = art.build_engine(Default::default()).unwrap();
    let mut rng = Pcg32::new(SEED, 99);
    let mut x = vec![0.0f32; DIMS[0]];
    let mut y = vec![0.0f32; DIMS[2]];
    let mut wire_sig = Vec::new();
    for _ in 0..8 {
        for v in x.iter_mut() {
            *v = rng.uniform_range(-1.0, 1.0);
        }
        remote.forward(&x, &mut y).unwrap();
        wire_sig.extend(y.iter().map(|v| v.to_bits()));
    }
    assert_eq!(wire_sig, clean_sig, "healed hub must serve the converged engine");
}

/// The end-to-end crash-safety loop at every supported width: an actor
/// dies, a partition window severs hub publishes, and the learner hangs
/// mid-run; the watchdog detects the stale heartbeat, cancels the
/// attempt, and restarts from the latest checkpoint *including its
/// replay section* — and the final engine is bit-identical to the
/// fault-free replay-coupled run's.
#[test]
fn watchdog_restart_after_kill_partition_and_hang_is_bit_exact_at_every_width() {
    let dir = std::env::temp_dir().join("quarl_faults_chaos_watchdog");
    let _ = std::fs::remove_dir_all(&dir);
    for precision in all_precisions() {
        let label = precision.label();
        let (clean_sig, clean_trains, _) =
            run_spec(RunSpec { replay: true, ..RunSpec::new(precision) }).unwrap();

        let hang_at = (clean_trains * 2 / 5).max(11);
        let plan = Arc::new(
            FaultPlan::new(SEED).kill_actor(0, 40).partition(2, 4).hang_learner(hang_at),
        );
        let hub = Arc::new(SnapshotHub::new());
        let path = dir.join(format!("{label}.qckp"));
        let _ = std::fs::remove_file(&path);
        let wcfg = WatchdogConfig {
            ckpt_path: path.clone(),
            deadline: Duration::from_millis(200),
            max_restarts: 2,
            restart_backoff: Duration::from_millis(2),
        };
        let policy = CheckpointPolicy { path: path.clone(), every_trains: 10 };
        let supervised = supervise(&wcfg, |resume, hb| {
            run_spec(RunSpec {
                faults: Some(Arc::clone(&plan)),
                ckpt: Some(policy.clone()),
                resume_from: resume.as_ref(),
                hub: Some(Arc::clone(&hub)),
                watchdog: Some(hb),
                replay: true,
                ..RunSpec::new(precision)
            })
        })
        .unwrap();
        assert!(
            supervised.restart_count() >= 1,
            "{label}: the hang must be detected and restarted"
        );
        assert!(
            supervised.restarts.iter().any(|r| r.cause == RestartCause::Hang),
            "{label}: at least one restart must be heartbeat-driven, got {:?}",
            supervised.restarts.iter().map(|r| &r.cause).collect::<Vec<_>>()
        );
        assert!(supervised.recovery_ms() > 0.0, "{label}");
        let (sig, trains, _) = supervised.value;
        assert_eq!(trains, clean_trains, "{label}: the restart pays the remaining trains");
        assert_eq!(sig, clean_sig, "{label}: watchdog recovery must be bit-exact");
        assert_eq!(plan.count(FaultKind::ActorKill), 1, "{label}: the kill fired");
        assert_eq!(plan.partition_windows(), 1, "{label}: the partition was observed");
        assert_eq!(plan.count(FaultKind::LearnerHang), 1, "{label}: the hang fired once");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn every_corrupted_or_truncated_checkpoint_byte_is_a_typed_error() {
    let params = init_params(9);
    let mut rng = Pcg32::new(9, 4242);
    for _ in 0..13 {
        rng.next_u32();
    }
    let ckpt = Checkpoint {
        train_steps: 42,
        env_steps: 184,
        broadcasts: 4,
        version: 4,
        replay_pushed: 203,
        rng: rng.state_parts(),
        params,
        replay: None,
    };
    let blob = ckpt.to_bytes();
    assert_eq!(Checkpoint::from_bytes(&blob).unwrap(), ckpt, "pristine blob must verify");

    for i in 0..blob.len() {
        let mut bad = blob.clone();
        bad[i] ^= 0xFF;
        let err = Checkpoint::from_bytes(&bad)
            .expect_err(&format!("flipped byte {i} must be detected"));
        // Every rejection is a typed SnapshotError, surfaced before any
        // state is restored.
        let _: &SnapshotError = &err;
    }
    for len in 0..blob.len() {
        Checkpoint::from_bytes(&blob[..len])
            .expect_err(&format!("truncation to {len} bytes must be detected"));
    }
}

/// Same exhaustive corruption sweep over blobs that carry a replay
/// section — wrapped prioritized and wrapped uniform — so every byte of
/// the new section (manifest fields, sampler RNG, payload tiles, CRCs)
/// is provably covered by a typed check.
#[test]
fn every_corrupted_or_truncated_replay_checkpoint_byte_is_a_typed_error() {
    let mut smp = Pcg32::new(5, 555);
    for _ in 0..17 {
        smp.next_u32();
    }

    // Wrapped PER: 23 pushes into a 16-slot ring, shaped priorities.
    let mut per = PrioritizedReplay::new(16, DIMS[0], 1, 0.6);
    for k in 0..23 {
        let o = [k as f32, -(k as f32), 0.5, 1.0];
        let a = [(k % 2) as f32];
        per.push(Transition { obs: &o, action: &a, reward: 0.1 * k as f32, next_obs: &o, done: k % 5 == 0 });
    }
    let idx: Vec<usize> = (0..16).collect();
    let td: Vec<f32> = (0..16).map(|k| 0.02 * (k as f32 + 1.0)).collect();
    per.update_priorities(&idx, &td);

    // Wrapped uniform ring: 19 pushes into 16 slots.
    let mut buf = ReplayBuffer::new(16, DIMS[0], 1);
    for k in 0..19 {
        let o = [k as f32, 0.25, -0.5, 2.0];
        let a = [1.0];
        buf.push(Transition { obs: &o, action: &a, reward: k as f32, next_obs: &o, done: false });
    }

    let sections = [
        ReplaySection {
            replay: ReplayCkpt::Prioritized(per.state()),
            sampler_rng: smp.state_parts(),
        },
        ReplaySection { replay: ReplayCkpt::Uniform(buf.state()), sampler_rng: smp.state_parts() },
    ];
    for section in sections {
        let ckpt = Checkpoint {
            train_steps: 23,
            env_steps: 146,
            broadcasts: 2,
            version: 2,
            replay_pushed: 23,
            rng: Pcg32::new(9, 4242).state_parts(),
            params: init_params(9),
            replay: Some(section),
        };
        let blob = ckpt.to_bytes();
        let back = Checkpoint::from_bytes(&blob).unwrap();
        assert_eq!(back, ckpt, "pristine replay blob must verify");

        for i in 0..blob.len() {
            let mut bad = blob.clone();
            bad[i] ^= 0xFF;
            let err = Checkpoint::from_bytes(&bad)
                .expect_err(&format!("flipped byte {i} must be detected"));
            let _: &SnapshotError = &err;
        }
        for len in 0..blob.len() {
            Checkpoint::from_bytes(&blob[..len])
                .expect_err(&format!("truncation to {len} bytes must be detected"));
        }
    }
}

//! End-to-end integration: abbreviated training runs through the full
//! Rust -> PJRT -> AOT-program stack for every algorithm, plus PTQ and
//! QAT evaluation paths. These are smoke-scale (seconds, not minutes);
//! convergence-scale runs live in the experiment harness.

use quarl::algos::{a2c, ddpg, dqn, ppo, QuantSchedule};
use quarl::coordinator::{evaluate, EvalMode};
use quarl::quant::PtqMethod;
use quarl::runtime::Runtime;

fn artifacts() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then(|| Runtime::new(dir).unwrap())
}

#[test]
fn dqn_short_run_and_all_eval_modes() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = dqn::DqnConfig::new("cartpole");
    cfg.total_steps = 2_000;
    cfg.warmup = 200;
    cfg.seed = 1;
    let (policy, log) = dqn::train(&rt, &cfg).unwrap();
    assert!(log.episodes > 0);
    for mode in [
        EvalMode::AsTrained,
        EvalMode::Ptq(PtqMethod::Fp16),
        EvalMode::Ptq(PtqMethod::Int(8)),
        EvalMode::Ptq(PtqMethod::Int(2)),
        EvalMode::Ptq(PtqMethod::IntPerAxis(8)),
    ] {
        let e = evaluate(&rt, &policy, 3, mode, 2).unwrap();
        assert!(e.mean_reward.is_finite());
        assert!(e.mean_reward >= 1.0, "cartpole episodes are >= 1 step");
    }
}

#[test]
fn dqn_qat_short_run_trains_and_captures_ranges() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = dqn::DqnConfig::new("cartpole");
    cfg.total_steps = 2_000;
    cfg.warmup = 200;
    cfg.quant = QuantSchedule::qat(8, 1_000);
    cfg.seed = 2;
    let (policy, _log) = dqn::train(&rt, &cfg).unwrap();
    // ranges must have been monitored (non-degenerate rows)
    let qs = policy.qstate.data();
    assert!(qs.iter().any(|&v| v != 0.0), "qstate never updated");
    let e = evaluate(&rt, &policy, 3, EvalMode::AsTrained, 3).unwrap();
    assert!(e.mean_reward.is_finite());
}

#[test]
fn a2c_and_ppo_short_runs() {
    let Some(rt) = artifacts() else { return };
    let mut ca = a2c::A2cConfig::new("cartpole");
    ca.total_steps = 4_000;
    ca.seed = 3;
    let (pa, la) = a2c::train(&rt, &ca).unwrap();
    assert!(la.episodes > 0);
    assert!(evaluate(&rt, &pa, 3, EvalMode::AsTrained, 1).unwrap().mean_reward.is_finite());

    let mut cp = ppo::PpoConfig::new("cartpole");
    cp.total_steps = 4_000;
    cp.seed = 3;
    let (pp, lp) = ppo::train(&rt, &cp).unwrap();
    assert!(lp.episodes > 0);
    let e = evaluate(&rt, &pp, 3, EvalMode::Ptq(PtqMethod::Int(8)), 1).unwrap();
    assert!(e.mean_reward.is_finite());
    assert!(e.action_dist_variance >= 0.0);
}

#[test]
fn ddpg_short_run() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = ddpg::DdpgConfig::new("pendulum");
    cfg.total_steps = 1_500;
    cfg.warmup = 300;
    cfg.seed = 4;
    let (policy, log) = ddpg::train(&rt, &cfg).unwrap();
    assert!(log.episodes > 0);
    let e = evaluate(&rt, &policy, 2, EvalMode::AsTrained, 1).unwrap();
    assert!(e.mean_reward.is_finite() && e.mean_reward <= 0.0);
}

#[test]
fn native_engines_match_xla_act_program() {
    // The deployment engines and the XLA act program must agree on the
    // greedy action for a trained DQN policy (fp32 engine near-exactly).
    let Some(rt) = artifacts() else { return };
    let mut cfg = dqn::DqnConfig::new("cartpole");
    cfg.total_steps = 1_500;
    cfg.warmup = 200;
    cfg.seed = 5;
    let (policy, _) = dqn::train(&rt, &cfg).unwrap();

    let act = rt.load(&format!("{}_act", policy.arch)).unwrap();
    let mut f32e = quarl::inference::EngineF32::from_params(&policy.params).unwrap();
    let mut rng = quarl::rng::Pcg32::new(6, 6);
    let mut agree = 0;
    let trials = 50;
    for _ in 0..trials {
        let obs: Vec<f32> = (0..4).map(|_| rng.uniform_range(-0.2, 0.2)).collect();
        let mut inputs: Vec<quarl::tensor::Tensor> = policy.params.tensors.clone();
        inputs.push(policy.qstate.clone());
        inputs.push(quarl::tensor::Tensor::new(vec![1, 4], obs.clone()).unwrap());
        inputs.push(quarl::tensor::Tensor::vec1(&[0.0, 0.0, 1e9]));
        let q_xla = act.run(&inputs).unwrap();
        let mut q_native = vec![0.0f32; 2];
        f32e.forward(&obs, &mut q_native);
        if quarl::tensor::argmax(q_xla[0].row(0)) == quarl::tensor::argmax(&q_native) {
            agree += 1;
        }
    }
    assert!(agree >= trials - 2, "argmax agreement {agree}/{trials}");
}

//! Sustainability-subsystem invariants: deterministic energy attribution
//! under a fake clock, carbon-report arithmetic against hand-computed
//! values, and JSON round-trips of the machine-readable reports.
//! Everything here runs offline (no PJRT, no artifacts).

use std::sync::Arc;

use quarl::actorq::Precision;
use quarl::runtime::json::Json;
use quarl::sustain::{
    mlp_forward_joules, mlp_macs, mlp_weight_bytes, CarbonComparison, CarbonIntensity,
    CarbonReport, Component, EnergyLine, EnergyMeter, FakeClock, PowerModel,
};

#[test]
fn fake_clock_attribution_is_exact_and_deterministic() {
    let clock = Arc::new(FakeClock::new());
    let meter = EnergyMeter::with_clock(clock.clone());

    // learner: 3 scopes of 2s; actors: 4 scopes of 250ms; broadcast: 1ms
    for _ in 0..3 {
        let _t = meter.scope(Component::Learner);
        clock.advance_secs(2.0);
    }
    for _ in 0..4 {
        let _t = meter.scope(Component::Actors);
        clock.advance_nanos(250_000_000);
        meter.add_steps(Component::Actors, 64);
    }
    {
        let _t = meter.scope(Component::Broadcast);
        clock.advance_nanos(1_000_000);
    }

    let snap = meter.snapshot();
    assert_eq!(snap.busy_secs("learner"), 6.0);
    assert_eq!(snap.busy_secs("actors"), 1.0);
    assert_eq!(snap.busy_secs("broadcast"), 1e-3);
    assert_eq!(snap.steps("actors"), 256);
    assert_eq!(snap.get("learner").unwrap().scopes, 3);
    assert!((snap.total_busy_secs() - 7.001).abs() < 1e-12);

    // untouched clock time (idle waits) is not billed
    clock.advance_secs(100.0);
    assert_eq!(meter.snapshot(), snap);
}

#[test]
fn snapshot_report_matches_hand_computed_emissions() {
    let clock = Arc::new(FakeClock::new());
    let meter = EnergyMeter::with_clock(clock.clone());
    {
        let _t = meter.scope(Component::Actors);
        clock.advance_secs(1000.0);
    }
    {
        let _t = meter.scope(Component::Learner);
        clock.advance_secs(500.0);
    }
    let power = PowerModel { cpu_watts: 18.0, accel_watts: 72.0 };
    let table = CarbonIntensity::builtin();
    let report =
        CarbonReport::from_snapshot("run", &meter.snapshot(), &power, "us", &table).unwrap();

    // actors: 1000 s x 18 W = 18 kJ = 5e-3 kWh
    // learner: 500 s x 72 W = 36 kJ = 1e-2 kWh
    assert_eq!(report.components.len(), 2, "broadcast recorded nothing, omitted");
    let actors = &report.components[0];
    assert_eq!(actors.component, "actors");
    assert!((actors.kwh - 5e-3).abs() < 1e-15);
    let learner = &report.components[1];
    assert!((learner.kwh - 1e-2).abs() < 1e-15);
    assert!((report.total_kwh - 1.5e-2).abs() < 1e-15);
    // at 386 gCO2/kWh: 15e-3 kWh -> 5.79 g -> 5.79e-3 kg
    assert!((report.total_kg_co2eq - 1.5e-2 * 386.0 / 1000.0).abs() < 1e-12);
    assert_eq!(report.g_co2_per_kwh, 386.0);
}

#[test]
fn comparison_ratio_against_hand_computed_values() {
    // fp32: 200 s at 50 W; int8: 80 s at 50 W; 400 gCO2/kWh.
    // kg_fp32 = 200*50/3.6e6 * 0.4 = 1.1111..e-3
    // ratio = 200/80 = 2.5 exactly (same watts, same grid)
    let g = 400.0;
    let fp32 = CarbonReport::from_lines(
        "cell/fp32",
        "test",
        g,
        vec![EnergyLine::compute("actors", 200.0, 10_000.0, 50.0, g)],
    );
    let int8 = CarbonReport::from_lines(
        "cell/int8",
        "test",
        g,
        vec![EnergyLine::compute("actors", 80.0, 10_000.0, 50.0, g)],
    );
    assert!((fp32.total_kg_co2eq - 200.0 * 50.0 / 3.6e6 * g / 1000.0).abs() < 1e-15);
    let cmp = CarbonComparison { label: "cell".into(), baseline: fp32, quantized: int8 };
    assert!((cmp.improvement() - 2.5).abs() < 1e-12);
}

#[test]
fn report_and_comparison_json_round_trip() {
    let g = CarbonIntensity::builtin().g_per_kwh("eu").unwrap();
    let mk = |label: &str, secs: f64, watts: f64| {
        CarbonReport::from_lines(
            label,
            "eu",
            g,
            vec![
                EnergyLine::compute("actors", secs, 30_000.0, watts, g),
                EnergyLine::compute("learner", secs / 3.0, 1_500.0, 15.0, g),
            ],
        )
    };
    let cmp = CarbonComparison {
        label: "dqn/cartpole".into(),
        baseline: mk("dqn/cartpole/fp32", 12.25, 9.5),
        quantized: mk("dqn/cartpole/int8", 3.5, 2.125),
    };
    let text = quarl::runtime::json::to_string(&cmp.to_json());
    let back = CarbonComparison::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, cmp);
    assert!((back.improvement() - cmp.improvement()).abs() < 1e-12);

    // every ratio input is present in the serialized form
    let parsed = Json::parse(&text).unwrap();
    let line = &parsed.get("baseline").unwrap().get("components").unwrap().as_arr().unwrap()[0];
    for key in ["busy_secs", "watts", "kwh", "kg_co2eq", "steps"] {
        assert!(line.opt(key).is_some(), "missing {key}");
    }
    assert!(parsed.get("baseline").unwrap().opt("g_co2_per_kwh").is_some());
    assert!(parsed.opt("kg_co2eq_ratio").is_some());
}

#[test]
fn flop_model_favours_int8_and_matches_counts() {
    let dims = [4usize, 64, 64, 2];
    assert_eq!(mlp_macs(&dims), 4480.0);
    assert_eq!(mlp_weight_bytes(&dims, Precision::Fp32), 4.0 * 4480.0 + 130.0 * 4.0);
    assert_eq!(mlp_weight_bytes(&dims, Precision::Int(8)), 4480.0 + 130.0 * 4.0);
    let f = mlp_forward_joules(&dims, Precision::Fp32);
    let q = mlp_forward_joules(&dims, Precision::Int(8));
    assert!(f > 0.0 && q > 0.0 && f > q);
    // ratio must clear the acceptance bar (> 1.0) with margin
    assert!(f / q > 2.0, "modeled fp32:int8 energy ratio {:.2}", f / q);
}

#[test]
fn carbon_config_overlay_round_trips_through_disk() {
    let dir = std::env::temp_dir().join("quarl_sustain_test");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("regions.json");
    std::fs::write(&path, r#"{"regions": {"testgrid": 123.5, "us": 1.0}}"#).unwrap();
    let table = CarbonIntensity::load(Some(&path)).unwrap();
    assert_eq!(table.g_per_kwh("testgrid").unwrap(), 123.5);
    assert_eq!(table.g_per_kwh("us").unwrap(), 1.0, "overlay shadows builtin");
    assert!(table.g_per_kwh("eu").unwrap() > 0.0, "builtin regions survive");
    assert!(CarbonIntensity::load(Some(&dir.join("missing.json"))).is_err());
}

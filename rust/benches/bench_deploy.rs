//! Bench: Fig-6 deployment latency — fp32 vs int8 vs packed int4 native
//! inference for the three NavLite policy sizes (plus the RasPi-class
//! swap model).
//!
//!     cargo bench --bench bench_deploy

use quarl::bench_util::{bench, black_box};
use quarl::inference::{EngineF32, EngineInt4, EngineInt8, MemModel};
use quarl::rng::Pcg32;
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

fn main() {
    println!("== Fig 6: deployment inference latency (native engines) ==");
    let policies: [(&str, Vec<usize>); 3] = [
        ("policy_I  (3L MLP 64)", vec![12, 64, 64, 64, 25]),
        ("policy_II (3L MLP 256)", vec![12, 256, 256, 256, 25]),
        ("policy_III (4096,512,1024)", vec![12, 4096, 512, 1024, 25]),
    ];
    let mem = MemModel::raspi3b();
    for (name, dims) in policies {
        let params = mlp_params(&dims, 7);
        let mut f32e = EngineF32::from_params(&params).unwrap();
        let mut i8e = EngineInt8::from_params(&params).unwrap();
        let mut i4e = EngineInt4::from_params(&params).unwrap();
        let x: Vec<f32> = (0..dims[0]).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut out = vec![0.0f32; *dims.last().unwrap()];
        let (iters, batches) = if dims[1] >= 4096 { (20, 10) } else { (200, 10) };
        let f = bench(&format!("{name} fp32"), iters, batches, || {
            f32e.forward(black_box(&x), &mut out);
        });
        let q = bench(&format!("{name} int8"), iters, batches, || {
            i8e.forward(black_box(&x), &mut out).unwrap();
        });
        let q4 = bench(&format!("{name} int4"), iters, batches, || {
            i4e.forward(black_box(&x), &mut out).unwrap();
        });
        let f32_mem = f32e.memory_bytes();
        let i8_mem = i8e.memory_bytes();
        let i4_mem = i4e.memory_bytes();
        println!(
            "  speedup int8 {:.2}x, int4 {:.2}x | mem {:.2} MiB -> {:.2} / {:.2} MiB | raspi swap penalty fp32 {:.1} ms, int8 {:.1} ms, int4 {:.1} ms",
            f.median_ns / q.median_ns,
            f.median_ns / q4.median_ns,
            f32_mem as f64 / (1 << 20) as f64,
            i8_mem as f64 / (1 << 20) as f64,
            i4_mem as f64 / (1 << 20) as f64,
            mem.swap_penalty_secs(f32_mem) * 1e3,
            mem.swap_penalty_secs(i8_mem) * 1e3,
            mem.swap_penalty_secs(i4_mem) * 1e3,
        );
    }
}

//! Bench: quantizer throughput — per-tensor/per-axis affine and fp16
//! rounding (the PTQ cost model behind Table 2 / Fig 7 sweeps).
//!
//!     cargo bench --bench bench_quant

use quarl::bench_util::{bench, black_box};
use quarl::quant::{fake_quant_per_axis, fake_quant_slice, fp16_quant_slice};
use quarl::rng::Pcg32;
use quarl::tensor::Tensor;

fn main() {
    println!("== quantizer throughput ==");
    let mut rng = Pcg32::new(3, 3);
    for n in [1_024usize, 65_536, 1_048_576] {
        let base: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut buf = base.clone();
        bench(&format!("affine int8 per-tensor n={n}"), 20, 10, || {
            buf.copy_from_slice(&base);
            fake_quant_slice(black_box(&mut buf), 8).unwrap();
        });
        bench(&format!("fp16 round-trip n={n}"), 20, 10, || {
            buf.copy_from_slice(&base);
            fp16_quant_slice(black_box(&mut buf));
        });
    }
    let rows = 512;
    let cols = 512;
    let base: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
    let mut t = Tensor::new(vec![rows, cols], base.clone()).unwrap();
    bench(&format!("affine int8 per-axis {rows}x{cols}"), 20, 10, || {
        t.data_mut().copy_from_slice(&base);
        fake_quant_per_axis(black_box(&mut t), 8).unwrap();
    });
}

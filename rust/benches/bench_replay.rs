//! Bench: replay-buffer hot paths — PER push / stratified sample /
//! priority update at DQN batch sizes (L3 §Perf item).
//!
//!     cargo bench --bench bench_replay

use quarl::bench_util::bench;
use quarl::replay::{PrioritizedReplay, ReplayBuffer, Transition};
use quarl::rng::Pcg32;

fn main() {
    println!("== replay throughput ==");
    let obs_dim = 8;
    let mut rng = Pcg32::new(1, 1);
    let obs = vec![0.3f32; obs_dim];

    let mut uni = ReplayBuffer::new(100_000, obs_dim, 1);
    for _ in 0..100_000 {
        uni.push(Transition { obs: &obs, action: &[1.0], reward: 0.5, next_obs: &obs, done: false });
    }
    bench("uniform push", 10_000, 8, || {
        uni.push(Transition { obs: &obs, action: &[1.0], reward: 0.5, next_obs: &obs, done: false });
    });
    bench("uniform sample B=64", 500, 8, || {
        let _ = uni.sample(64, &mut rng);
    });

    let mut per = PrioritizedReplay::new(100_000, obs_dim, 1, 0.6);
    for _ in 0..100_000 {
        per.push(Transition { obs: &obs, action: &[1.0], reward: 0.5, next_obs: &obs, done: false });
    }
    bench("PER push", 10_000, 8, || {
        per.push(Transition { obs: &obs, action: &[1.0], reward: 0.5, next_obs: &obs, done: false });
    });
    let mut indices = vec![0usize; 64];
    let mut tds = vec![0.1f32; 64];
    bench("PER sample B=64 (stratified)", 500, 8, || {
        let b = per.sample(64, 0.5, &mut rng);
        indices.copy_from_slice(&b.indices);
    });
    bench("PER priority update B=64", 2_000, 8, || {
        for (i, t) in tds.iter_mut().enumerate() {
            *t = (i as f32 * 0.37).sin().abs();
        }
        per.update_priorities(&indices, &tds);
    });
}

//! Bench: batched inference kernels — rows/sec of `forward_batch` vs the
//! per-row scalar `forward` across batch size x layer width x engine
//! precision x kernel variant (fp32 baseline plus every `--bits` entry
//! on the generic quantized engine; packed nibbles below int5, packed
//! crumbs at int2, XNOR-popcount bitplanes at int1/ternary).
//!
//!     cargo bench --bench bench_engines
//!     cargo bench --bench bench_engines -- --bits 1,2,4,8,t
//!     cargo bench --bench bench_engines -- --threads 4
//!     cargo bench --bench bench_engines -- --quick --bits 1,2,4,8  # CI smoke
//!
//! `--bits` takes the validated CLI precision list (integer widths
//! 1..=8 plus "t"/"ternary" — exactly the engine-supported set; the CLI
//! rejects anything else up front). The fp32 baseline always runs.
//! `--quick` trims the sweep to the two narrowest MLPs for the CI
//! sanity-check job (width 256 stays in so the intra-op pool actually
//! engages — at width 64 every layer fits one column block and the
//! threaded variant would silently measure the single-thread path).
//! `--threads T` (> 1) measures the prepacked kernel of every quantized
//! width with T intra-op workers; int8 is measured threaded (2 workers
//! minimum) in every run, and the summary records
//! `int8_threads2_vs_1_b64` — threaded-vs-single batched throughput at
//! the widest width of the sweep — as the persistent worker pool's
//! before/after figure (per-call `thread::scope` spawns used to eat the
//! win at these layer sizes).
//!
//! Every quantized width is measured on BOTH kernel variants, tagged in
//! the `kernel` row field, so `BENCH_engines.json` records the
//! before/after of the panel-major rework:
//!
//! * `"panel"`    — construction-time panel-major prepack + SWAR bulk
//!   unpack + 4x4 microkernel (the default affine engine);
//! * `"rowmajor"` — the PR-4 input-major kernel (strided gather +
//!   per-code unpack inside the tile loop), kept as the reference;
//! * `"bitplane"` — the XNOR-popcount SWAR kernel (int1/ternary only;
//!   these precisions have a single layout, so no rowmajor variant);
//! * `"base"`     — the fp32 baseline engine (one layout).
//!
//! Acceptance shape: at batch 64 on the 128x512x512x25 MLP the int8
//! batched kernel clears >= 2x the scalar per-row rows/sec (the weight
//! panel is streamed once per batch instead of once per row — the
//! paper's memory-bandwidth argument along the batch axis), and the
//! int4 panel kernel beats the int4 rowmajor kernel on the wide layers
//! (`int4_panel_vs_rowmajor_b64_w512` > 1: the SWAR unpack + sequential
//! panels recover the throughput the scalar nibble unpack left behind).
//!
//! Output: the human-readable rows, then exactly one machine-readable
//! JSON summary line (also written to `BENCH_engines.json`) so the
//! kernel's trajectory is tracked across PRs alongside
//! `BENCH_actorq.json`. Each row carries `engine` ("fp32"/"int8"/
//! "int4"/...), `bits` (32 for fp32), `kernel`, `threads`, `width`,
//! `batch`, scalar/batched rows-per-sec, and their ratio.

use std::collections::BTreeMap;

use quarl::bench_util::{bench, black_box};
use quarl::config::cli::Args;
use quarl::coordinator::metrics::write_json_file;
use quarl::inference::{engine_for_cfg, Engine, EngineConfig, KernelKind};
use quarl::quant::Precision;
use quarl::rng::Pcg32;
use quarl::runtime::json::{to_string, Json};
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;

const IN_DIM: usize = 128;
const OUT_DIM: usize = 25;
const WIDTHS: [usize; 3] = [64, 256, 512];
const BATCHES: [usize; 3] = [1, 8, 64];

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

/// One measured engine variant of the sweep.
struct Variant {
    precision: Precision,
    /// Row tag: "base" for fp32, else the kernel label.
    kernel: &'static str,
    threads: usize,
    engine: Box<dyn Engine + Send>,
}

/// Build the variant list for one width: fp32 baseline, then per
/// quantized precision the prepacked kernel (threads 1), the PR-4
/// row-major reference, and a threaded prepacked variant — every
/// quantized precision when the user asked for `--threads > 1`, and
/// int8 in *every* run (at 2 workers minimum) so the persistent-pool
/// spawn-overhead before/after row is recorded even in CI quick mode.
fn build_variants(params: &ParamSet, precisions: &[Precision], threads: usize) -> Vec<Variant> {
    let mut out = Vec::new();
    for &p in precisions {
        if p == Precision::Fp32 {
            out.push(Variant {
                precision: p,
                kernel: "base",
                threads: 1,
                engine: engine_for_cfg(params, p, EngineConfig::default()).unwrap(),
            });
            continue;
        }
        if p.is_bitplane() {
            // One layout only: the XNOR-popcount words. No rowmajor
            // reference exists for these precisions.
            out.push(Variant {
                precision: p,
                kernel: "bitplane",
                threads: 1,
                engine: engine_for_cfg(params, p, EngineConfig::default()).unwrap(),
            });
            if threads > 1 {
                out.push(Variant {
                    precision: p,
                    kernel: "bitplane",
                    threads,
                    engine: engine_for_cfg(params, p, EngineConfig::with_threads(threads))
                        .unwrap(),
                });
            }
            continue;
        }
        out.push(Variant {
            precision: p,
            kernel: KernelKind::Prepacked.label(),
            threads: 1,
            engine: engine_for_cfg(params, p, EngineConfig::default()).unwrap(),
        });
        out.push(Variant {
            precision: p,
            kernel: KernelKind::RowMajor.label(),
            threads: 1,
            engine: engine_for_cfg(
                params,
                p,
                EngineConfig { kernel: KernelKind::RowMajor, ..EngineConfig::default() },
            )
            .unwrap(),
        });
        let t = if threads > 1 {
            threads
        } else if p == Precision::Int(8) {
            2
        } else {
            1
        };
        if t > 1 {
            out.push(Variant {
                precision: p,
                kernel: KernelKind::Prepacked.label(),
                threads: t,
                engine: engine_for_cfg(params, p, EngineConfig::with_threads(t)).unwrap(),
            });
        }
    }
    out
}

/// JSON row for one engine x kernel x width x batch cell from the two
/// measured per-sweep medians (ns).
fn cell_row(v: &Variant, width: usize, batch: usize, scalar_ns: f64, batched_ns: f64) -> Json {
    let rows_scalar = batch as f64 / (scalar_ns * 1e-9);
    let rows_batched = batch as f64 / (batched_ns * 1e-9);
    println!(
        "    -> {rows_scalar:>12.0} rows/s scalar, {rows_batched:>12.0} rows/s batched ({:.2}x)",
        scalar_ns / batched_ns
    );
    let mut row = BTreeMap::new();
    row.insert("engine".to_string(), Json::Str(v.precision.label()));
    row.insert("bits".to_string(), Json::Num(v.precision.bits() as f64));
    row.insert("kernel".to_string(), Json::Str(v.kernel.to_string()));
    row.insert("threads".to_string(), Json::Num(v.threads as f64));
    row.insert("width".to_string(), Json::Num(width as f64));
    row.insert("batch".to_string(), Json::Num(batch as f64));
    row.insert("rows_per_sec_scalar".to_string(), Json::Num(rows_scalar));
    row.insert("rows_per_sec_batched".to_string(), Json::Num(rows_batched));
    row.insert("speedup".to_string(), Json::Num(scalar_ns / batched_ns));
    Json::Obj(row)
}

/// Measure one (engine, batch) cell: rep-amortized scalar per-row loop
/// vs one batched sweep. Returns (scalar_ns, batched_ns) medians.
fn measure(
    eng: &mut dyn Engine,
    tag: &str,
    xs: &[f32],
    batch: usize,
    out: &mut [f32],
    iters: usize,
    batches: usize,
) -> (f64, f64) {
    let s_ns = bench(&format!("{tag} scalar"), iters, batches, || {
        for r in 0..batch {
            eng.forward(
                black_box(&xs[r * IN_DIM..(r + 1) * IN_DIM]),
                &mut out[r * OUT_DIM..(r + 1) * OUT_DIM],
            )
            .unwrap();
        }
    })
    .median_ns;
    let b_ns = bench(&format!("{tag} batched"), iters, batches, || {
        eng.forward_batch(black_box(xs), batch, out).unwrap();
    })
    .median_ns;
    (s_ns, b_ns)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("bench args");
    let swept = args
        .precisions(&[Precision::Int(1), Precision::Int(2), Precision::Int(4), Precision::Int(8)])
        .expect("--bits");
    let threads = args.get_usize("threads", 1).expect("--threads").max(1);
    let quick = args.has("quick");
    let widths: &[usize] = if quick { &WIDTHS[..2] } else { &WIDTHS };
    // Widest width of this sweep: the threaded-vs-single summary cell
    // lives there (threading needs >= 2 column blocks to engage).
    let wide = *widths.last().unwrap();

    // fp32 always; the CLI has already validated every sweep entry
    // against engine support (integer widths 1..=8 plus ternary).
    let mut precisions = vec![Precision::Fp32];
    precisions.extend(swept);

    println!("== batched inference kernels: forward_batch vs per-row forward ==");
    let mut rows: Vec<Json> = Vec::new();
    let mut headline = f64::NAN;
    // (rowmajor batched ns, panel batched ns) for the int4 wide cell
    let mut int4_wide: (f64, f64) = (f64::NAN, f64::NAN);
    // (threads=1 batched ns, threaded batched ns) for the int8 panel
    // kernel at (widest width, batch 64) — the worker-pool before/after.
    let mut int8_threaded: (f64, f64) = (f64::NAN, f64::NAN);
    // (int8 panel batched ns, int1 bitplane batched ns) at (width 512,
    // batch 64) — the XNOR-popcount before/after headline.
    let mut int1_vs_int8: (f64, f64) = (f64::NAN, f64::NAN);
    for &width in widths {
        let dims = [IN_DIM, width, width, OUT_DIM];
        let params = mlp_params(&dims, 7);
        // Build each engine once per width (quantization + the panel
        // repack are offline work, not part of the measured cells); the
        // batch loop then reuses them so the engine-owned scratch arenas
        // grow once to the high-water batch, as they would in a
        // deployed sweep.
        let mut variants = build_variants(&params, &precisions, threads);
        let mut rng = Pcg32::new(42, 42);
        for batch in BATCHES {
            let xs: Vec<f32> =
                (0..batch * IN_DIM).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
            let mut out = vec![0.0f32; batch * OUT_DIM];
            // Keep wall time bounded: wide nets (and the CI quick mode)
            // get fewer iterations (one "iter" is a whole batch sweep
            // either way).
            let (iters, batches) = if quick {
                (3, 3)
            } else if width >= 512 {
                (3, 7)
            } else {
                (20, 7)
            };

            for v in variants.iter_mut() {
                let tag = format!(
                    "{} {} t={} {IN_DIM}x{width}x{width}x{OUT_DIM} b={batch}",
                    v.precision.label(),
                    v.kernel,
                    v.threads
                );
                let (s_ns, b_ns) =
                    measure(v.engine.as_mut(), &tag, &xs, batch, &mut out, iters, batches);
                let headline_cell = width == 512 && batch == 64 && v.threads == 1;
                if headline_cell && v.precision == Precision::Int(8) && v.kernel == "panel" {
                    headline = s_ns / b_ns;
                    int1_vs_int8.0 = b_ns;
                }
                if headline_cell && v.precision == Precision::Int(1) && v.kernel == "bitplane" {
                    int1_vs_int8.1 = b_ns;
                }
                if headline_cell && v.precision == Precision::Int(4) {
                    match v.kernel {
                        "rowmajor" => int4_wide.0 = b_ns,
                        "panel" => int4_wide.1 = b_ns,
                        _ => {}
                    }
                }
                if width == wide
                    && batch == 64
                    && v.precision == Precision::Int(8)
                    && v.kernel == "panel"
                {
                    if v.threads == 1 {
                        int8_threaded.0 = b_ns;
                    } else {
                        int8_threaded.1 = b_ns;
                    }
                }
                rows.push(cell_row(v, width, batch, s_ns, b_ns));
            }
        }
    }

    if headline.is_finite() {
        println!(
            "\n(headline: int8 batch-64 on the 128x512x512x25 MLP runs {headline:.2}x the\n\
             per-row scalar path — acceptance wants >= 2x.)"
        );
    } else {
        println!("\n(headline cell not in this sweep — run without --quick and with 8 in --bits)");
    }
    let int4_panel_gain = int4_wide.0 / int4_wide.1;
    if int4_panel_gain.is_finite() {
        println!(
            "(int4 wide-layer before/after: the prepacked panel kernel runs \
             {int4_panel_gain:.2}x the PR-4 rowmajor kernel at batch 64, width 512.)"
        );
    }
    let int8_threads_gain = int8_threaded.0 / int8_threaded.1;
    if int8_threads_gain.is_finite() {
        println!(
            "(int8 worker-pool before/after: the threaded panel kernel runs \
             {int8_threads_gain:.2}x the single-thread kernel at batch 64, width {wide} — \
             persistent pool, no per-call spawns.)"
        );
    }
    let int1_gain = int1_vs_int8.0 / int1_vs_int8.1;
    if int1_gain.is_finite() {
        println!(
            "(int1 XNOR-popcount before/after: the bitplane kernel runs {int1_gain:.2}x \
             the int8 panel kernel at batch 64, width 512 — 64 weights per xor+popcount.)"
        );
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("engines".into()));
    doc.insert("mlp".to_string(), Json::Str(format!("{IN_DIM}xWxWx{OUT_DIM}")));
    doc.insert(
        "bits".to_string(),
        Json::Arr(precisions.iter().map(|p| Json::Num(p.bits() as f64)).collect()),
    );
    doc.insert(
        "precisions".to_string(),
        Json::Arr(precisions.iter().map(|p| Json::Str(p.label())).collect()),
    );
    doc.insert("threads".to_string(), Json::Num(threads as f64));
    doc.insert("headline_int8_b64_w512_speedup".to_string(), Json::Num(headline));
    doc.insert(
        "int4_panel_vs_rowmajor_b64_w512".to_string(),
        Json::Num(int4_panel_gain),
    );
    doc.insert("int1_vs_int8_b64_w512".to_string(), Json::Num(int1_gain));
    doc.insert("int8_threads2_vs_1_b64".to_string(), Json::Num(int8_threads_gain));
    doc.insert("int8_threads2_vs_1_width".to_string(), Json::Num(wide as f64));
    doc.insert("rows".to_string(), Json::Arr(rows));
    let doc = Json::Obj(doc);
    // The single machine-readable summary line:
    println!("{}", to_string(&doc));
    match write_json_file("BENCH_engines.json", &doc) {
        Ok(()) => eprintln!("wrote BENCH_engines.json"),
        Err(e) => eprintln!("warning: BENCH_engines.json not written: {e}"),
    }
}

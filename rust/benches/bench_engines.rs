//! Bench: batched inference kernels — rows/sec of `forward_batch` vs the
//! per-row scalar `forward` across batch size x layer width, fp32 and
//! int8 engines (the GEMM-ification of the actor hot path).
//!
//!     cargo bench --bench bench_engines
//!
//! Acceptance shape: at batch 64 on the 128x512x512x25 MLP the int8
//! batched kernel clears >= 2x the scalar per-row rows/sec — the weight
//! panel is streamed once per batch instead of once per row, which is
//! the paper's memory-bandwidth argument applied along the batch axis.
//!
//! Output: the human-readable rows, then exactly one machine-readable
//! JSON summary line (also written to `BENCH_engines.json`) so the
//! kernel's trajectory is tracked across PRs alongside
//! `BENCH_actorq.json`.

use std::collections::BTreeMap;

use quarl::bench_util::{bench, black_box};
use quarl::coordinator::metrics::write_json_file;
use quarl::inference::{EngineF32, EngineInt8};
use quarl::rng::Pcg32;
use quarl::runtime::json::{to_string, Json};
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;

const IN_DIM: usize = 128;
const OUT_DIM: usize = 25;
const WIDTHS: [usize; 3] = [64, 256, 512];
const BATCHES: [usize; 3] = [1, 8, 64];

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

/// JSON row for one engine x width x batch cell from the two measured
/// per-sweep medians (ns).
fn cell_row(engine: &str, width: usize, batch: usize, scalar_ns: f64, batched_ns: f64) -> Json {
    let rows_scalar = batch as f64 / (scalar_ns * 1e-9);
    let rows_batched = batch as f64 / (batched_ns * 1e-9);
    println!(
        "    -> {rows_scalar:>12.0} rows/s scalar, {rows_batched:>12.0} rows/s batched ({:.2}x)",
        scalar_ns / batched_ns
    );
    let mut row = BTreeMap::new();
    row.insert("engine".to_string(), Json::Str(engine.into()));
    row.insert("width".to_string(), Json::Num(width as f64));
    row.insert("batch".to_string(), Json::Num(batch as f64));
    row.insert("rows_per_sec_scalar".to_string(), Json::Num(rows_scalar));
    row.insert("rows_per_sec_batched".to_string(), Json::Num(rows_batched));
    row.insert("speedup".to_string(), Json::Num(scalar_ns / batched_ns));
    Json::Obj(row)
}

fn main() {
    println!("== batched inference kernels: forward_batch vs per-row forward ==");
    let mut rows: Vec<Json> = Vec::new();
    let mut headline = 0.0f64;
    for width in WIDTHS {
        let dims = [IN_DIM, width, width, OUT_DIM];
        let params = mlp_params(&dims, 7);
        let mut f32e = EngineF32::from_params(&params).unwrap();
        let mut i8e = EngineInt8::from_params(&params).unwrap();
        let mut rng = Pcg32::new(42, 42);
        for batch in BATCHES {
            let xs: Vec<f32> =
                (0..batch * IN_DIM).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
            let mut out = vec![0.0f32; batch * OUT_DIM];
            // Keep wall time bounded: wide nets get fewer iterations
            // (one "iter" is a whole batch sweep either way).
            let (iters, batches) = if width >= 512 { (3, 7) } else { (20, 7) };

            let tag = format!("int8 {IN_DIM}x{width}x{width}x{OUT_DIM} b={batch}");
            let s_ns = bench(&format!("{tag} scalar"), iters, batches, || {
                for r in 0..batch {
                    i8e.forward(
                        black_box(&xs[r * IN_DIM..(r + 1) * IN_DIM]),
                        &mut out[r * OUT_DIM..(r + 1) * OUT_DIM],
                    )
                    .unwrap();
                }
            })
            .median_ns;
            let b_ns = bench(&format!("{tag} batched"), iters, batches, || {
                i8e.forward_batch(black_box(&xs), batch, &mut out).unwrap();
            })
            .median_ns;
            if width == 512 && batch == 64 {
                headline = s_ns / b_ns;
            }
            rows.push(cell_row("int8", width, batch, s_ns, b_ns));

            let tag = format!("fp32 {IN_DIM}x{width}x{width}x{OUT_DIM} b={batch}");
            let s_ns = bench(&format!("{tag} scalar"), iters, batches, || {
                for r in 0..batch {
                    f32e.forward(
                        black_box(&xs[r * IN_DIM..(r + 1) * IN_DIM]),
                        &mut out[r * OUT_DIM..(r + 1) * OUT_DIM],
                    );
                }
            })
            .median_ns;
            let b_ns = bench(&format!("{tag} batched"), iters, batches, || {
                f32e.forward_batch(black_box(&xs), batch, &mut out).unwrap();
            })
            .median_ns;
            rows.push(cell_row("fp32", width, batch, s_ns, b_ns));
        }
    }

    println!(
        "\n(headline: int8 batch-64 on the 128x512x512x25 MLP runs {headline:.2}x the\n\
         per-row scalar path — acceptance wants >= 2x.)"
    );

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("engines".into()));
    doc.insert("mlp".to_string(), Json::Str(format!("{IN_DIM}xWxWx{OUT_DIM}")));
    doc.insert("headline_int8_b64_w512_speedup".to_string(), Json::Num(headline));
    doc.insert("rows".to_string(), Json::Arr(rows));
    let doc = Json::Obj(doc);
    // The single machine-readable summary line:
    println!("{}", to_string(&doc));
    match write_json_file("BENCH_engines.json", &doc) {
        Ok(()) => eprintln!("wrote BENCH_engines.json"),
        Err(e) => eprintln!("warning: BENCH_engines.json not written: {e}"),
    }
}

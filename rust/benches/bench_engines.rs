//! Bench: batched inference kernels — rows/sec of `forward_batch` vs the
//! per-row scalar `forward` across batch size x layer width x engine
//! bitwidth (fp32 baseline plus every `--bits` width on the generic
//! quantized engine, packed two-codes-per-byte below int5).
//!
//!     cargo bench --bench bench_engines
//!     cargo bench --bench bench_engines -- --bits 2,4,8
//!     cargo bench --bench bench_engines -- --quick --bits 4,8   # CI smoke
//!
//! `--bits` takes the validated 2..=16 CLI list; widths without a native
//! engine (> 8) are skipped with a note. The fp32 baseline always runs.
//! `--quick` trims the sweep to the narrowest MLP for the CI
//! sanity-check job.
//!
//! Acceptance shape: at batch 64 on the 128x512x512x25 MLP the int8
//! batched kernel clears >= 2x the scalar per-row rows/sec — the weight
//! panel is streamed once per batch instead of once per row, which is
//! the paper's memory-bandwidth argument applied along the batch axis.
//! int4 rows track int8 (same integer GEMM; the nibble unpack is
//! amortized per panel) while halving the streamed weight bytes.
//!
//! Output: the human-readable rows, then exactly one machine-readable
//! JSON summary line (also written to `BENCH_engines.json`) so the
//! kernel's trajectory is tracked across PRs alongside
//! `BENCH_actorq.json`. Each row carries `engine` ("fp32"/"int8"/
//! "int4"/...), `bits` (32 for fp32), `width`, `batch`, scalar/batched
//! rows-per-sec, and their ratio.

use std::collections::BTreeMap;

use quarl::bench_util::{bench, black_box};
use quarl::config::cli::Args;
use quarl::coordinator::metrics::write_json_file;
use quarl::inference::Engine;
use quarl::quant::Precision;
use quarl::rng::Pcg32;
use quarl::runtime::json::{to_string, Json};
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;

const IN_DIM: usize = 128;
const OUT_DIM: usize = 25;
const WIDTHS: [usize; 3] = [64, 256, 512];
const BATCHES: [usize; 3] = [1, 8, 64];

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

/// JSON row for one engine x width x batch cell from the two measured
/// per-sweep medians (ns).
fn cell_row(
    precision: Precision,
    width: usize,
    batch: usize,
    scalar_ns: f64,
    batched_ns: f64,
) -> Json {
    let rows_scalar = batch as f64 / (scalar_ns * 1e-9);
    let rows_batched = batch as f64 / (batched_ns * 1e-9);
    println!(
        "    -> {rows_scalar:>12.0} rows/s scalar, {rows_batched:>12.0} rows/s batched ({:.2}x)",
        scalar_ns / batched_ns
    );
    let mut row = BTreeMap::new();
    row.insert("engine".to_string(), Json::Str(precision.label()));
    row.insert("bits".to_string(), Json::Num(precision.bits() as f64));
    row.insert("width".to_string(), Json::Num(width as f64));
    row.insert("batch".to_string(), Json::Num(batch as f64));
    row.insert("rows_per_sec_scalar".to_string(), Json::Num(rows_scalar));
    row.insert("rows_per_sec_batched".to_string(), Json::Num(rows_batched));
    row.insert("speedup".to_string(), Json::Num(scalar_ns / batched_ns));
    Json::Obj(row)
}

/// Measure one (engine, batch) cell: rep-amortized scalar per-row loop
/// vs one batched sweep. Returns (scalar_ns, batched_ns) medians.
fn measure(
    eng: &mut dyn Engine,
    tag: &str,
    xs: &[f32],
    batch: usize,
    out: &mut [f32],
    iters: usize,
    batches: usize,
) -> (f64, f64) {
    let s_ns = bench(&format!("{tag} scalar"), iters, batches, || {
        for r in 0..batch {
            eng.forward(
                black_box(&xs[r * IN_DIM..(r + 1) * IN_DIM]),
                &mut out[r * OUT_DIM..(r + 1) * OUT_DIM],
            )
            .unwrap();
        }
    })
    .median_ns;
    let b_ns = bench(&format!("{tag} batched"), iters, batches, || {
        eng.forward_batch(black_box(xs), batch, out).unwrap();
    })
    .median_ns;
    (s_ns, b_ns)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("bench args");
    let bits = args.bits(&[4, 8]).expect("--bits");
    let quick = args.has("quick");
    let widths: &[usize] = if quick { &WIDTHS[..1] } else { &WIDTHS };

    // fp32 always; then one quantized engine per requested width that
    // has a native engine (2..=8; the CLI validates 2..=16).
    let mut precisions = vec![Precision::Fp32];
    for &b in &bits {
        let p = Precision::Int(b);
        if p.engine_supported() {
            precisions.push(p);
        } else {
            eprintln!("note: skipping --bits {b} (native engines implement 2..=8)");
        }
    }

    println!("== batched inference kernels: forward_batch vs per-row forward ==");
    let mut rows: Vec<Json> = Vec::new();
    let mut headline = f64::NAN;
    for &width in widths {
        let dims = [IN_DIM, width, width, OUT_DIM];
        let params = mlp_params(&dims, 7);
        // Build each engine once per width (quantization is offline
        // work, not part of the measured cells); the batch loop then
        // reuses them so the engine-owned scratch arenas grow once to
        // the high-water batch, as they would in a deployed sweep.
        let mut engines: Vec<(Precision, Box<dyn Engine>)> = precisions
            .iter()
            .map(|&p| (p, quarl::inference::engine_for(&params, p).unwrap()))
            .collect();
        let mut rng = Pcg32::new(42, 42);
        for batch in BATCHES {
            let xs: Vec<f32> =
                (0..batch * IN_DIM).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
            let mut out = vec![0.0f32; batch * OUT_DIM];
            // Keep wall time bounded: wide nets (and the CI quick mode)
            // get fewer iterations (one "iter" is a whole batch sweep
            // either way).
            let (iters, batches) = if quick {
                (3, 3)
            } else if width >= 512 {
                (3, 7)
            } else {
                (20, 7)
            };

            for (precision, engine) in engines.iter_mut() {
                let precision = *precision;
                let tag = format!(
                    "{} {IN_DIM}x{width}x{width}x{OUT_DIM} b={batch}",
                    precision.label()
                );
                let (s_ns, b_ns) = measure(
                    engine.as_mut(),
                    &tag,
                    &xs,
                    batch,
                    &mut out,
                    iters,
                    batches,
                );
                if precision == Precision::Int(8) && width == 512 && batch == 64 {
                    headline = s_ns / b_ns;
                }
                rows.push(cell_row(precision, width, batch, s_ns, b_ns));
            }
        }
    }

    if headline.is_finite() {
        println!(
            "\n(headline: int8 batch-64 on the 128x512x512x25 MLP runs {headline:.2}x the\n\
             per-row scalar path — acceptance wants >= 2x.)"
        );
    } else {
        println!("\n(headline cell not in this sweep — run without --quick and with 8 in --bits)");
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("engines".into()));
    doc.insert("mlp".to_string(), Json::Str(format!("{IN_DIM}xWxWx{OUT_DIM}")));
    doc.insert(
        "bits".to_string(),
        Json::Arr(precisions.iter().map(|p| Json::Num(p.bits() as f64)).collect()),
    );
    doc.insert("headline_int8_b64_w512_speedup".to_string(), Json::Num(headline));
    doc.insert("rows".to_string(), Json::Arr(rows));
    let doc = Json::Obj(doc);
    // The single machine-readable summary line:
    println!("{}", to_string(&doc));
    match write_json_file("BENCH_engines.json", &doc) {
        Ok(()) => eprintln!("wrote BENCH_engines.json"),
        Err(e) => eprintln!("warning: BENCH_engines.json not written: {e}"),
    }
}

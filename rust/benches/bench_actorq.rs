//! Bench: ActorQ experience-collection throughput scaling — env steps/sec
//! drained by the learner thread as the actor pool grows, fp32 vs int8
//! actor policies (the paper's speedup-vs-actor-count axis, minus the
//! learner so the collection path is isolated).
//!
//!     cargo bench --bench bench_actorq
//!
//! Acceptance shape: throughput from 1 -> 4 actors scales >= 2x on any
//! machine with >= 4 cores (the pool is embarrassingly parallel; the
//! only shared state is the mpsc channel and the broadcast Arc).

use std::time::Duration;

use quarl::actorq::ActorPrecision;
use quarl::coordinator::exp_actorq::collection_rate;

fn main() {
    println!("== ActorQ collection throughput (cartpole, 64x64 policy) ==");
    let window = Duration::from_millis(1_500);
    for precision in [ActorPrecision::Int8, ActorPrecision::Fp32] {
        let mut base = 0.0f64;
        for actors in [1usize, 2, 4, 8] {
            let rate = collection_rate(actors, precision, 7, window).expect("pool run");
            if actors == 1 {
                base = rate;
            }
            let scale = if base > 0.0 { rate / base } else { 0.0 };
            println!(
                "{:<6} actors {:<2} {:>12.0} steps/s   ({:>5.2}x vs 1 actor)",
                precision.label(),
                actors,
                rate,
                scale
            );
        }
    }
    println!("\n(int8 rows track fp32 within the engine-speed delta; scaling is the");
    println!(" paper's §3 mechanism — collection parallelizes across all cores.)");
}

//! Bench: ActorQ experience-collection throughput scaling — env steps/sec
//! drained by the learner thread as the actor pool grows, fp32 vs int8
//! actor policies (the paper's speedup-vs-actor-count axis, minus the
//! learner so the collection path is isolated).
//!
//!     cargo bench --bench bench_actorq
//!
//! Acceptance shape: throughput from 1 -> 4 actors scales >= 2x on any
//! machine with >= 4 cores (the pool is embarrassingly parallel; the
//! only shared state is the mpsc channel and the broadcast Arc).
//!
//! Output: the human-readable rows, then exactly one machine-readable
//! JSON summary line (also written to `BENCH_actorq.json`) so the perf
//! trajectory can be tracked across PRs.

use std::collections::BTreeMap;
use std::time::Duration;

use quarl::actorq::Precision;
use quarl::coordinator::exp_actorq::collection_rate;
use quarl::coordinator::metrics::write_json_file;
use quarl::runtime::json::{to_string, Json};

fn main() {
    println!("== ActorQ collection throughput (cartpole, 64x64 policy) ==");
    let window = Duration::from_millis(1_500);
    let mut rows: Vec<Json> = Vec::new();
    for precision in [Precision::Int(8), Precision::Fp32] {
        let mut base = 0.0f64;
        for actors in [1usize, 2, 4, 8] {
            let rate = collection_rate(actors, precision, 7, window).expect("pool run");
            if actors == 1 {
                base = rate;
            }
            let scale = if base > 0.0 { rate / base } else { 0.0 };
            println!(
                "{:<6} actors {:<2} {:>12.0} steps/s   ({:>5.2}x vs 1 actor)",
                precision.label(),
                actors,
                rate,
                scale
            );
            let mut row = BTreeMap::new();
            row.insert("precision".to_string(), Json::Str(precision.label().into()));
            row.insert("actors".to_string(), Json::Num(actors as f64));
            row.insert("steps_per_sec".to_string(), Json::Num(rate));
            row.insert("scale_vs_1_actor".to_string(), Json::Num(scale));
            rows.push(Json::Obj(row));
        }
    }
    println!("\n(int8 rows track fp32 within the engine-speed delta; scaling is the");
    println!(" paper's §3 mechanism — collection parallelizes across all cores.)");

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("actorq".into()));
    doc.insert("env".to_string(), Json::Str("cartpole".into()));
    doc.insert("window_ms".to_string(), Json::Num(window.as_millis() as f64));
    doc.insert("rows".to_string(), Json::Arr(rows));
    let doc = Json::Obj(doc);
    // The single machine-readable summary line:
    println!("{}", to_string(&doc));
    match write_json_file("BENCH_actorq.json", &doc) {
        Ok(()) => eprintln!("wrote BENCH_actorq.json"),
        Err(e) => eprintln!("warning: BENCH_actorq.json not written: {e}"),
    }
}

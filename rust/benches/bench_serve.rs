//! Bench: the dynamic-batching policy server — served per-query latency
//! (p50/p99 from the log-linear histogram) and the batch sizes the
//! deadline window coalesces, swept over precision x client count.
//!
//!     cargo bench --bench bench_serve
//!     cargo bench --bench bench_serve -- --bits 2,4,8
//!     cargo bench --bench bench_serve -- --threads 4 --window-us 500
//!     cargo bench --bench bench_serve -- --quick            # CI smoke
//!
//! Each cell moves a fresh engine onto a [`PolicyServer`] and drives it
//! closed-loop from N client threads until the query budget is spent.
//! Closed-loop clients make `mean_batch` track concurrency: one client
//! can never coalesce (that row is the latency floor — scalar GEMV plus
//! channel hops), while at 16 clients the window folds concurrent
//! queries into one `forward_batch` call and qps rides the engines'
//! batched roofline. Latency is enqueue-to-reply, so queueing delay is
//! included — this is what a caller of `query()` actually waits, not
//! the bare GEMM.
//!
//! `--bits` adds quantized widths beyond the fp32 + int8 defaults
//! (validated 2..=16; widths without a native engine are skipped with a
//! note). `--window-us` / `--max-batch` are the two batching knobs;
//! `--threads` sets the engine's intra-op workers (shared persistent
//! pool). `--quick` trims clients and the query budget for CI.
//!
//! Output: one human line per cell, then exactly one machine-readable
//! JSON summary line, also written to `BENCH_serve.json` — the same
//! schema `exp serve` emits (checked by
//! `scripts/check_bench_reports.py` in CI), so either entry point feeds
//! the serving trajectory.

use std::collections::BTreeMap;
use std::time::Duration;

use quarl::config::cli::Args;
use quarl::coordinator::metrics::write_json_file;
use quarl::inference::{engine_for_cfg, EngineConfig};
use quarl::quant::Precision;
use quarl::rng::{mix_seed, Pcg32};
use quarl::runtime::json::{to_string, Json};
use quarl::runtime::manifest::TensorSpec;
use quarl::runtime::ParamSet;
use quarl::serve::{PolicyServer, ServeConfig, ServeReport};

/// Policy shape: wide enough that batching amortizes real weight traffic
/// (and the threaded engines have > 1 column block per mid layer).
const DIMS: [usize; 4] = [64, 256, 256, 8];

const CLIENTS: [usize; 3] = [1, 4, 16];

fn mlp_params(dims: &[usize], seed: u64) -> ParamSet {
    let mut specs = Vec::new();
    for i in 0..dims.len() - 1 {
        specs.push(TensorSpec { name: format!("q.w{i}"), shape: vec![dims[i], dims[i + 1]] });
        specs.push(TensorSpec { name: format!("q.b{i}"), shape: vec![dims[i + 1]] });
    }
    let mut rng = Pcg32::new(seed, 1);
    ParamSet::init(&specs, &mut rng)
}

/// Drive one (precision, clients) cell: `queries` closed-loop requests
/// split across `clients` threads against a fresh server.
fn serve_cell(
    precision: Precision,
    clients: usize,
    queries: usize,
    threads: usize,
    cfg: ServeConfig,
) -> ServeReport {
    let params = mlp_params(&DIMS, 31);
    let engine =
        engine_for_cfg(&params, precision, EngineConfig::with_threads(threads)).unwrap();
    let (server, client) = PolicyServer::spawn(engine, cfg);
    let per_client = queries / clients;
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let cl = client.clone();
            // remainder lands on client 0 so the total is exact
            let mine = per_client + if c == 0 { queries % clients } else { 0 };
            let seed = mix_seed(97, c as u64);
            std::thread::spawn(move || {
                let mut rng = Pcg32::new(seed, 17);
                let mut obs = vec![0.0f32; DIMS[0]];
                for _ in 0..mine {
                    for v in obs.iter_mut() {
                        *v = rng.uniform_range(-1.0, 1.0);
                    }
                    cl.query(&obs).expect("serve query");
                }
            })
        })
        .collect();
    drop(client);
    for j in joins {
        j.join().expect("client thread");
    }
    server.shutdown()
}

/// JSON row for one cell — the `exp serve` row schema.
fn cell_row(
    precision: Precision,
    clients: usize,
    report: &ServeReport,
    cfg: &ServeConfig,
    window_us: u64,
) -> Json {
    let hist: Vec<Json> =
        report.batches.counts().iter().map(|&c| Json::Num(c as f64)).collect();
    let mut row = BTreeMap::new();
    row.insert("engine".to_string(), Json::Str(precision.label()));
    row.insert("bits".to_string(), Json::Num(precision.bits() as f64));
    row.insert("clients".to_string(), Json::Num(clients as f64));
    row.insert("queries".to_string(), Json::Num(report.queries as f64));
    row.insert("rejected".to_string(), Json::Num(report.rejected as f64));
    row.insert("qps".to_string(), Json::Num(report.qps()));
    row.insert("p50_us".to_string(), Json::Num(report.latency.p50_us()));
    row.insert("p99_us".to_string(), Json::Num(report.latency.p99_us()));
    row.insert("mean_us".to_string(), Json::Num(report.latency.mean_us()));
    row.insert("mean_batch".to_string(), Json::Num(report.batches.mean()));
    row.insert("max_batch_seen".to_string(), Json::Num(report.batches.max_seen() as f64));
    row.insert("batch_hist".to_string(), Json::Arr(hist));
    row.insert("window_us".to_string(), Json::Num(window_us as f64));
    row.insert("max_batch".to_string(), Json::Num(cfg.max_batch as f64));
    row.insert("wall_secs".to_string(), Json::Num(report.wall_secs));
    Json::Obj(row)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).expect("bench args");
    let swept = args.precisions(&[]).expect("--bits");
    let threads = args.get_usize("threads", 1).expect("--threads").max(1);
    let window_us = args.get_u64("window-us", 250).expect("--window-us");
    let max_batch = args.get_usize("max-batch", 32).expect("--max-batch").max(1);
    let quick = args.has("quick");
    let clients: &[usize] = if quick { &CLIENTS[..2] } else { &CLIENTS };
    let queries = if quick { 400 } else { 4_000 };

    let cfg = ServeConfig {
        max_batch,
        window: Duration::from_micros(window_us),
        queue_capacity: 1024,
        ..ServeConfig::default()
    };

    // fp32 baseline + int8 headline always; --bits adds the rest of the
    // native precisions (integer widths 1..=8 plus ternary, already
    // CLI-validated against engine support) opt-in.
    let mut precisions = vec![Precision::Fp32, Precision::Int(8)];
    precisions.extend(swept.iter().copied().filter(|&p| p != Precision::Int(8)));

    let mlp = DIMS.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x");
    println!(
        "== policy serving: dynamic batching (mlp {mlp}, window {window_us} us, \
         max_batch {max_batch}, engine threads {threads}) =="
    );
    let mut rows: Vec<Json> = Vec::new();
    for &p in &precisions {
        for &c in clients {
            let report = serve_cell(p, c, queries, threads, cfg);
            println!(
                "  {:>5} c={c:<2} {:>8.0} qps  p50 {:>7.1} us  p99 {:>7.1} us  \
                 mean_batch {:>5.2}  max_seen {}",
                p.label(),
                report.qps(),
                report.latency.p50_us(),
                report.latency.p99_us(),
                report.batches.mean(),
                report.batches.max_seen()
            );
            rows.push(cell_row(p, c, &report, &cfg, window_us));
        }
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("serve".into()));
    doc.insert("mlp".to_string(), Json::Str(mlp));
    doc.insert("window_us".to_string(), Json::Num(window_us as f64));
    doc.insert("max_batch".to_string(), Json::Num(max_batch as f64));
    doc.insert("rows".to_string(), Json::Arr(rows));
    let doc = Json::Obj(doc);
    // The single machine-readable summary line:
    println!("{}", to_string(&doc));
    match write_json_file("BENCH_serve.json", &doc) {
        Ok(()) => eprintln!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("warning: BENCH_serve.json not written: {e}"),
    }
}

//! Bench: PJRT hot-path costs — act-program latency, train-program
//! latency, and the host-side literal conversion overhead (the L3 items
//! of EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench bench_runtime

use quarl::bench_util::{bench, black_box};
use quarl::rng::Pcg32;
use quarl::runtime::client::tensor_to_literal;
use quarl::runtime::{ParamSet, Runtime};
use quarl::tensor::Tensor;

fn main() {
    let Ok(rt) = Runtime::new("artifacts") else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    println!("== runtime hot paths ==");

    // literal conversion overhead
    for n in [64usize, 4_096, 262_144] {
        let t = Tensor::full(vec![n], 1.5);
        bench(&format!("tensor->literal n={n}"), 200, 10, || {
            let _ = black_box(tensor_to_literal(&t).unwrap());
        });
    }

    // act program end-to-end (the per-env-step cost in DQN)
    let arch = rt.manifest.arch_for("dqn/cartpole").unwrap().to_string();
    let act = rt.load(&format!("{arch}_act")).unwrap();
    let n_p = act.spec.count("n_params").unwrap();
    let mut rng = Pcg32::new(1, 1);
    let params = ParamSet::init(&act.spec.inputs[..n_p], &mut rng);
    let mut inputs: Vec<Tensor> = params.tensors.clone();
    inputs.push(Tensor::zeros(vec![act.spec.n_qstate, 2]));
    inputs.push(Tensor::full(vec![1, 4], 0.05));
    inputs.push(Tensor::vec1(&[0.0, 0.0, 1e9]));
    bench("dqn/cartpole act program", 100, 10, || {
        let _ = black_box(act.run(&inputs).unwrap());
    });

    // train program end-to-end (the per-update cost)
    let train = rt.load(&format!("{arch}_train")).unwrap();
    let spec = &train.spec;
    let zeros = params.zeros_like();
    let mut tin: Vec<Tensor> = Vec::new();
    tin.extend(params.tensors.iter().cloned());
    tin.extend(params.tensors.iter().cloned());
    tin.extend(zeros.tensors.iter().cloned());
    tin.extend(zeros.tensors.iter().cloned());
    for spec_t in &spec.inputs[4 * n_p..spec.inputs.len() - 1] {
        tin.push(Tensor::zeros(spec_t.shape.clone()));
    }
    tin.push(Tensor::vec1(&[2.5e-4, 0.99, 0.0, 0.0, 1e9, 1.0]));
    bench("dqn/cartpole train program", 50, 10, || {
        let _ = black_box(train.run(&tin).unwrap());
    });
}

//! Bench: Table-4 mixed-precision train-step latency — fp32 vs bf16
//! AOT programs for DQN-Pong policies A/B/C through PJRT.
//!
//!     cargo bench --bench bench_mixed_precision
//!
//! Requires `make artifacts`. This is the microbenchmark companion to
//! `quarl exp table4` (which times full training runs).

use quarl::bench_util::bench;
use quarl::rng::Pcg32;
use quarl::runtime::{ParamSet, Runtime};
use quarl::tensor::Tensor;

fn main() {
    let Ok(rt) = Runtime::new("artifacts") else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    println!("== Table 4: DQN train-step latency, fp32 vs bf16 compute ==");
    for pol in ["mp_a", "mp_b", "mp_c"] {
        let mut medians = Vec::new();
        for prec in ["", "_bf16"] {
            let key = format!("dqn/pong_lite/{pol}{prec}");
            let arch = rt.manifest.arch_for(&key).expect("arch").to_string();
            let prog = rt.load(&format!("{arch}_train")).expect("program");
            let spec = &prog.spec;
            let n_p = spec.count("n_params").unwrap();
            let mut rng = Pcg32::new(5, 5);
            let params = ParamSet::init(&spec.inputs[..n_p], &mut rng);
            let zeros = params.zeros_like();
            let mut inputs: Vec<Tensor> = Vec::new();
            inputs.extend(params.tensors.iter().cloned());
            inputs.extend(params.tensors.iter().cloned());
            inputs.extend(zeros.tensors.iter().cloned());
            inputs.extend(zeros.tensors.iter().cloned());
            for spec_t in &spec.inputs[4 * n_p..spec.inputs.len() - 1] {
                inputs.push(Tensor::zeros(spec_t.shape.clone()));
            }
            inputs.push(Tensor::vec1(&[2.5e-4, 0.99, 0.0, 0.0, 1e9, 1.0]));
            let label = format!("{pol}{} train-step", if prec.is_empty() { " fp32" } else { " bf16" });
            let iters = if pol == "mp_c" { 3 } else { 10 };
            let st = bench(&label, iters, 8, || {
                let _ = prog.run(&inputs).expect("run");
            });
            medians.push(st.median_ns);
        }
        println!("  {pol}: bf16 speedup {:.2}x", medians[0] / medians[1]);
    }
}

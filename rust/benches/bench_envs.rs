//! Bench: environment step throughput — the simulators must never be the
//! training bottleneck (L3 §Perf item).
//!
//!     cargo bench --bench bench_envs

use quarl::bench_util::bench;
use quarl::envs::api::{Action, ActionSpace};
use quarl::envs::registry::{make_env, ENV_IDS};
use quarl::rng::Pcg32;

fn main() {
    println!("== environment step throughput ==");
    for id in ENV_IDS {
        let mut env = make_env(id).unwrap();
        let mut rng = Pcg32::new(1, 1);
        let mut obs = vec![0.0f32; env.obs_dim()];
        env.reset(&mut rng, &mut obs);
        let space = env.action_space();
        bench(&format!("{id} step"), 2_000, 8, || {
            let a = match &space {
                ActionSpace::Discrete(n) => Action::Discrete(rng.below_usize(*n)),
                ActionSpace::Continuous(d) => {
                    Action::Continuous((0..*d).map(|_| rng.uniform_range(-1.0, 1.0)).collect())
                }
            };
            let s = env.step(&a, &mut rng, &mut obs);
            if s.done {
                env.reset(&mut rng, &mut obs);
            }
        });
    }
}

//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate (xla-rs, wrapping the PJRT C API the way
//! `/opt/xla-example` does) is not vendorable offline, so this stub
//! provides the exact API surface `quarl::runtime::client` compiles
//! against. Host-side types ([`Literal`]) behave for real; everything
//! that needs an actual PJRT runtime ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`]) returns a descriptive error, so:
//!
//! * `cargo build` / `cargo test` work everywhere — the PJRT-gated
//!   integration tests skip themselves when `artifacts/` is absent, and
//!   everything pure-Rust (envs, replay, quantization, inference
//!   engines, the ActorQ actor pool) runs for real.
//! * Swapping in the real bindings is a one-line change to the `xla`
//!   path dependency in `rust/Cargo.toml`; no source edits.

use std::fmt;

/// Stub error: every runtime entry point produces one of these.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} needs the real PJRT bindings (point the `xla` \
         dependency in rust/Cargo.toml at them and rebuild)"
    )))
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug, Clone)]
pub struct PjRtClient;

/// One PJRT device (stub: never instantiated).
#[derive(Debug)]
pub struct PjRtDevice;

/// A device-resident buffer (stub: never instantiated).
#[derive(Debug)]
pub struct PjRtBuffer;

/// A compiled executable (stub: never instantiated).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

/// Parsed HLO module (stub: never instantiated).
#[derive(Debug)]
pub struct HloModuleProto;

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

/// Host-side literal: shape-carrying f32 data. Fully functional — the
/// coordinator builds these before upload, so they must work offline.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn devices(&self) -> Vec<PjRtDevice> {
        Vec::new()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("buffer_from_host_literal")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl Literal {
    /// Rank-1 literal from host data.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from(x)).collect())
    }

    /// Decompose a tuple literal (stub: tuples only come from device
    /// readback, which the stub cannot produce).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn runtime_entry_points_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla stub"));
    }
}

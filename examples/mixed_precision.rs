//! Mixed-precision training case study (paper §5, Table 4 / Fig 5):
//! train the DQN-Pong policy-A network with fp32 and bf16 compute and
//! compare train-step wallclock and convergence.
//!
//!     make artifacts && cargo run --release --example mixed_precision

use quarl::algos::dqn::{self, DqnConfig};
use quarl::runtime::Runtime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rt = Runtime::new("artifacts")?;
    let steps = 10_000;
    for (label, variant) in [("fp32", "mp_a"), ("bf16", "mp_a_bf16")] {
        let mut cfg = DqnConfig::new("pong_lite");
        cfg.arch_key = Some(format!("dqn/pong_lite/{variant}"));
        cfg.total_steps = steps;
        cfg.seed = 9;
        let (_policy, log) = dqn::train(&rt, &cfg)?;
        println!(
            "{label:>5}: train-exec {:.2}s over {steps} steps, wall {:.1}s, final return {:.1}",
            log.train_exec_secs, log.wall_secs, log.final_return
        );
    }
    println!(
        "\npaper shape: speedup grows with network size (policies B/C —\n\
         run `quarl exp table4` for the full sweep)."
    );
    Ok(())
}

//! Deployment case study (paper §5 / Fig 6): train a NavLite navigation
//! policy, quantize it to int8, and compare the native fp32 and int8
//! inference engines on latency, memory, and task success — including
//! the RasPi-3b-class swap model that produces the paper's 14-18x.
//!
//!     make artifacts && cargo run --release --example deploy_quantized

use std::time::Instant;

use quarl::algos::dqn::{self, DqnConfig};
use quarl::envs::api::{Action, Env};
use quarl::envs::nav_lite::NavLite;
use quarl::inference::{EngineF32, EngineInt8, MemModel};
use quarl::rng::Pcg32;
use quarl::runtime::Runtime;

fn success_rate(
    forward: &mut dyn FnMut(&[f32], &mut [f32]),
    episodes: usize,
) -> (f32, f64) {
    let mut env = NavLite::new(0.6);
    let mut rng = Pcg32::new(11, 3);
    let mut obs = vec![0.0f32; env.obs_dim()];
    let mut logits = vec![0.0f32; 25];
    let mut wins = 0;
    let mut secs = 0.0;
    let mut n = 0usize;
    for _ in 0..episodes {
        env.reset(&mut rng, &mut obs);
        loop {
            let t0 = Instant::now();
            forward(&obs, &mut logits);
            secs += t0.elapsed().as_secs_f64();
            n += 1;
            let a = logits
                .iter()
                .enumerate()
                .fold((0, f32::NEG_INFINITY), |acc, (i, &q)| if q > acc.1 { (i, q) } else { acc })
                .0;
            let s = env.step(&Action::Discrete(a), &mut rng, &mut obs);
            if s.done {
                if s.reward > 500.0 {
                    wins += 1;
                }
                break;
            }
        }
    }
    (wins as f32 / episodes as f32, secs / n as f64)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rt = Runtime::new("artifacts")?;
    // Policy II of the paper: 3-layer 256-wide MLP.
    let mut cfg = DqnConfig::new("nav_lite");
    cfg.arch_key = Some("dqn/nav_lite/nav_p2".into());
    cfg.total_steps = 20_000;
    cfg.seed = 4;
    println!("training NavLite policy II ({} steps) ...", cfg.total_steps);
    let (policy, log) = dqn::train(&rt, &cfg)?;
    println!("trained: final_return {:.0} ({} episodes)", log.final_return, log.episodes);

    let mut f32e = EngineF32::from_params(&policy.params)?;
    let mut i8e = EngineInt8::from_params(&policy.params)?;
    let (sr_f, lat_f) = success_rate(&mut |x, o| f32e.forward(x, o), 40);
    let (sr_q, lat_q) = success_rate(&mut |x, o| i8e.forward(x, o).unwrap(), 40);

    let mem = MemModel::raspi3b();
    let (mf, mq) = (f32e.memory_bytes(), i8e.memory_bytes());
    println!("\nFig-6-style row (policy II):");
    println!(
        "fp32: {:.3} ms/infer, success {:.0}%, weights {:.2} MiB",
        lat_f * 1e3, sr_f * 100.0, mf as f64 / (1 << 20) as f64
    );
    println!(
        "int8: {:.3} ms/infer, success {:.0}%, weights {:.2} MiB",
        lat_q * 1e3, sr_q * 100.0, mq as f64 / (1 << 20) as f64
    );
    println!(
        "speedup {:.2}x, memory ratio {:.2}x, raspi swap penalty fp32 {:.1} ms -> int8 {:.1} ms",
        lat_f / lat_q,
        mf as f64 / mq as f64,
        mem.swap_penalty_secs(mf) * 1e3,
        mem.swap_penalty_secs(mq) * 1e3,
    );
    Ok(())
}

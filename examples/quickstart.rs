//! Quickstart: train DQN on CartPole through the full three-layer stack
//! (Rust coordinator -> PJRT -> AOT XLA programs containing the Pallas
//! fake-quant kernels), then apply post-training quantization and print
//! a Table-2-style row.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Pass `--actorq` to train through the ActorQ actor-learner driver
//! instead (paper §3): four int8 actor threads collect experience on the
//! pure-Rust deployment engines while the learner trains in fp32 —
//! `dqn::train_actorq` / `ddpg::train_actorq` are the entry points.
//!
//!     cargo run --release --example quickstart -- --actorq

use quarl::actorq::{ActorQConfig, Precision};
use quarl::algos::dqn::{self, DqnConfig};
use quarl::coordinator::{evaluate, EvalMode};
use quarl::quant::{relative_error_pct, PtqMethod};
use quarl::runtime::Runtime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rt = Runtime::new("artifacts")?;
    println!("platform: {}", rt.platform_name());

    let mut cfg = DqnConfig::new("cartpole");
    cfg.total_steps = 40_000;
    cfg.log_every = 2_000;
    cfg.seed = 3;

    let use_actorq = std::env::args().any(|a| a == "--actorq");
    let policy = if use_actorq {
        let acfg = ActorQConfig::new(4).with_precision(Precision::Int(8));
        println!(
            "training dqn/cartpole (ActorQ: {} int8 actors) for {} steps ...",
            acfg.n_actors, cfg.total_steps
        );
        let (policy, log) = dqn::train_actorq(&rt, &cfg, &acfg)?;
        println!(
            "trained: episodes={} final_return={:.1} wall={:.1}s \
             ({} trains, {} broadcasts, {:.0} env steps/s)",
            log.episodes,
            log.final_return,
            log.wall_secs,
            log.train_steps,
            log.broadcasts,
            log.steps_per_sec
        );
        for (s, r) in &log.returns {
            println!("  step {s:>6}  return {r:.1}");
        }
        policy
    } else {
        println!("training dqn/cartpole for {} steps ...", cfg.total_steps);
        let (policy, log) = dqn::train(&rt, &cfg)?;
        println!(
            "trained: episodes={} final_return={:.1} wall={:.1}s (train-exec {:.1}s)",
            log.episodes, log.final_return, log.wall_secs, log.train_exec_secs
        );
        for (s, r) in &log.returns {
            println!("  step {s:>6}  return {r:.1}");
        }
        policy
    };

    let fp32 = evaluate(&rt, &policy, 30, EvalMode::AsTrained, 1)?;
    let fp16 = evaluate(&rt, &policy, 30, EvalMode::Ptq(PtqMethod::Fp16), 1)?;
    let int8 = evaluate(&rt, &policy, 30, EvalMode::Ptq(PtqMethod::Int(8)), 1)?;
    println!("\nPTQ (paper Table 2 row):");
    println!(
        "cartpole  fp32 {:.0}  fp16 {:.0} (E={:.2}%)  int8 {:.0} (E={:.2}%)",
        fp32.mean_reward,
        fp16.mean_reward,
        relative_error_pct(fp32.mean_reward, fp16.mean_reward),
        int8.mean_reward,
        relative_error_pct(fp32.mean_reward, int8.mean_reward),
    );
    Ok(())
}

//! Quickstart: train DQN on CartPole through the full three-layer stack
//! (Rust coordinator -> PJRT -> AOT XLA programs containing the Pallas
//! fake-quant kernels), then apply post-training quantization and print
//! a Table-2-style row.
//!
//!     make artifacts && cargo run --release --example quickstart

use quarl::algos::dqn::{self, DqnConfig};
use quarl::coordinator::{evaluate, EvalMode};
use quarl::quant::{relative_error_pct, PtqMethod};
use quarl::runtime::Runtime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rt = Runtime::new("artifacts")?;
    println!("platform: {}", rt.platform_name());

    let mut cfg = DqnConfig::new("cartpole");
    cfg.total_steps = 40_000;
    cfg.log_every = 2_000;
    cfg.seed = 3;
    println!("training dqn/cartpole for {} steps ...", cfg.total_steps);
    let (policy, log) = dqn::train(&rt, &cfg)?;
    println!(
        "trained: episodes={} final_return={:.1} wall={:.1}s (train-exec {:.1}s)",
        log.episodes, log.final_return, log.wall_secs, log.train_exec_secs
    );
    for (s, r) in &log.returns {
        println!("  step {s:>6}  return {r:.1}");
    }

    let fp32 = evaluate(&rt, &policy, 30, EvalMode::AsTrained, 1)?;
    let fp16 = evaluate(&rt, &policy, 30, EvalMode::Ptq(PtqMethod::Fp16), 1)?;
    let int8 = evaluate(&rt, &policy, 30, EvalMode::Ptq(PtqMethod::Int(8)), 1)?;
    println!("\nPTQ (paper Table 2 row):");
    println!(
        "cartpole  fp32 {:.0}  fp16 {:.0} (E={:.2}%)  int8 {:.0} (E={:.2}%)",
        fp32.mean_reward,
        fp16.mean_reward,
        relative_error_pct(fp32.mean_reward, fp16.mean_reward),
        int8.mean_reward,
        relative_error_pct(fp32.mean_reward, int8.mean_reward),
    );
    Ok(())
}

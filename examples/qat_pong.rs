//! Quantization-aware training on the Pong proxy (paper §3.2 / Fig 2):
//! train PPO with 8-bit and 4-bit fake quantization (quant delay = half
//! of training), compare against the fp32 baseline and 8-bit PTQ.
//!
//!     make artifacts && cargo run --release --example qat_pong

use quarl::algos::ppo::{self, PpoConfig};
use quarl::algos::QuantSchedule;
use quarl::coordinator::{evaluate, EvalMode};
use quarl::quant::PtqMethod;
use quarl::runtime::Runtime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let rt = Runtime::new("artifacts")?;
    let steps = 80_000;
    let episodes = 20;

    let mut base = PpoConfig::new("pong_lite");
    base.total_steps = steps;
    base.seed = 5;

    println!("training fp32 baseline ({steps} steps) ...");
    let (fp_policy, fp_log) = ppo::train(&rt, &base)?;
    let fp = evaluate(&rt, &fp_policy, episodes, EvalMode::AsTrained, 1)?;
    let ptq8 = evaluate(&rt, &fp_policy, episodes, EvalMode::Ptq(PtqMethod::Int(8)), 1)?;
    println!(
        "fp32: reward {:.1}  (train wall {:.0}s)   8-bit PTQ: {:.1}",
        fp.mean_reward, fp_log.wall_secs, ptq8.mean_reward
    );

    for bits in [8u32, 4] {
        let mut cfg = base.clone();
        cfg.quant = QuantSchedule::qat(bits, steps / 2);
        println!("training QAT-{bits} (delay {} steps) ...", steps / 2);
        let (policy, _log) = ppo::train(&rt, &cfg)?;
        // QAT evaluation keeps quantization on with the trained ranges
        // (paper Algorithm 2 line 4).
        let e = evaluate(&rt, &policy, episodes, EvalMode::AsTrained, 1)?;
        println!(
            "QAT-{bits}: reward {:.1}  action-dist variance {:.4}",
            e.mean_reward, e.action_dist_variance
        );
    }
    println!("\npaper shape: QAT-8 ~ fp32 >= PTQ-8, QAT-4 degrades modestly.");
    Ok(())
}

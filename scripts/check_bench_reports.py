#!/usr/bin/env python3
"""Sanity-check emitted BENCH_*.json reports: each file must parse as
JSON and carry the expected top-level keys, and sweep-style reports must
contain at least one row. Used by CI after running the offline bench /
experiment paths; also handy locally:

    python3 scripts/check_bench_reports.py rust/BENCH_engines.json ...

Exit code 0 = all files OK; 1 = any file missing, unparseable, or
missing keys.
"""

import json
import sys

# file-name prefix -> (required top-level keys, key holding the row list or None)
EXPECTATIONS = {
    "BENCH_engines": (["bench", "mlp", "bits", "headline_int8_b64_w512_speedup", "rows"], "rows"),
    "BENCH_actorq": (["bench", "env", "window_ms", "rows"], "rows"),
    "BENCH_carbon": (["bench", "regions_billed", "cells", "mean_kg_co2eq_ratio"], "cells"),
}


def check(path: str) -> list:
    errors = []
    name = path.rsplit("/", 1)[-1].rsplit(".", 1)[0]
    expected = EXPECTATIONS.get(name)
    if expected is None:
        return [f"{path}: no expectations registered for '{name}'"]
    keys, rows_key = expected
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        return [f"{path}: missing"]
    except json.JSONDecodeError as e:
        return [f"{path}: invalid JSON ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level is {type(doc).__name__}, expected object"]
    for k in keys:
        if k not in doc:
            errors.append(f"{path}: missing top-level key '{k}'")
    if rows_key and isinstance(doc.get(rows_key), list) and not doc[rows_key]:
        errors.append(f"{path}: '{rows_key}' is empty")
    return errors


def main(argv: list) -> int:
    if not argv:
        print("usage: check_bench_reports.py BENCH_*.json...", file=sys.stderr)
        return 1
    all_errors = []
    for path in argv:
        errs = check(path)
        if errs:
            all_errors.extend(errs)
        else:
            print(f"ok: {path}")
    for e in all_errors:
        print(f"error: {e}", file=sys.stderr)
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

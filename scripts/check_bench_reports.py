#!/usr/bin/env python3
"""Sanity-check emitted BENCH_*.json reports: each file must parse as
JSON and carry the expected top-level keys, and sweep-style reports must
contain at least one row. BENCH_engines.json additionally gets a
per-row schema check (kernel-variant + threads tagging, the before/after
kernel rows the panel-major rework is tracked by, and the int1/ternary
bitplane-kernel rows with the `int1_vs_int8_b64_w512` headline);
BENCH_serve.json gets one too (latency percentiles ordered, batch
histograms present, client counts sane), BENCH_noise.json gets the
QeRL-ladder check (fp32 baseline rung present, unique rungs,
fp32-normalized rewards), and BENCH_faults.json gets the chaos check
(actor kill absorbed, learner watchdog tripped with positive recovery
latency, partition window opened, straggler flagged, drain bounced the
retained client, and every mismatch column — faulted / resumed /
watchdog / served — exactly zero). Used by CI after running the offline bench /
experiment paths; also handy locally:

    python3 scripts/check_bench_reports.py rust/BENCH_engines.json ...

Exit code 0 = all files OK; 1 = any file missing, unparseable, or
missing keys.
"""

import json
import sys

# file-name prefix -> (required top-level keys, key holding the row list or None)
EXPECTATIONS = {
    "BENCH_engines": (
        [
            "bench",
            "mlp",
            "bits",
            "precisions",
            "threads",
            "headline_int8_b64_w512_speedup",
            "int4_panel_vs_rowmajor_b64_w512",
            "int8_threads2_vs_1_b64",
            "int1_vs_int8_b64_w512",
            "rows",
        ],
        "rows",
    ),
    "BENCH_noise": (["bench", "env", "rows"], "rows"),
    "BENCH_actorq": (["bench", "env", "window_ms", "rows"], "rows"),
    "BENCH_carbon": (["bench", "regions_billed", "cells", "mean_kg_co2eq_ratio"], "cells"),
    "BENCH_serve": (["bench", "mlp", "window_us", "max_batch", "rows"], "rows"),
    "BENCH_snapshot": (["bench", "mlp", "rows"], "rows"),
    "BENCH_faults": (["bench", "rows"], "rows"),
}

ENGINE_ROW_KEYS = [
    "engine",
    "bits",
    "kernel",
    "threads",
    "width",
    "batch",
    "rows_per_sec_scalar",
    "rows_per_sec_batched",
    "speedup",
]
KERNELS = {"base", "panel", "rowmajor", "bitplane"}
# Precisions stored as sign bitplanes and run on the XNOR-popcount
# kernel; they have exactly one layout, so no panel/rowmajor pairing.
BITPLANE_ENGINES = {"int1", "ternary"}


def check_engine_rows(path: str, doc: dict) -> list:
    """BENCH_engines.json row schema: every row tagged with a known
    kernel variant and a positive integer thread count; fp32 rows are
    the single-layout baseline; int1/ternary rows must run the
    XNOR-popcount 'bitplane' kernel (and nothing else may claim it);
    every affine quantized width present must be measured on BOTH the
    panel and rowmajor kernels (the before/after the panel rework is
    tracked by); and every precision the sweep lists must actually have
    rows — a swept format must not silently fall out of the tracked
    comparison (keyed by engine label, not bit width, because ternary
    and int2 share bits=2)."""
    errors = []
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return [f"{path}: 'rows' is not a list"]
    quant_kernels = {}  # affine engine label -> set of kernel tags seen
    seen_engines = set()
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{path}: rows[{i}] is not an object")
            continue
        for k in ENGINE_ROW_KEYS:
            if k not in row:
                errors.append(f"{path}: rows[{i}] missing key '{k}'")
        kernel = row.get("kernel")
        if kernel not in KERNELS:
            errors.append(f"{path}: rows[{i}] kernel '{kernel}' not in {sorted(KERNELS)}")
        threads = row.get("threads")
        if not (isinstance(threads, (int, float)) and threads >= 1 and threads == int(threads)):
            errors.append(f"{path}: rows[{i}] threads '{threads}' is not a positive integer")
        engine = row.get("engine")
        seen_engines.add(engine)
        if engine == "fp32":
            if kernel != "base":
                errors.append(f"{path}: rows[{i}] fp32 row must carry kernel 'base'")
        elif engine in BITPLANE_ENGINES:
            if kernel != "bitplane":
                errors.append(
                    f"{path}: rows[{i}] {engine} row carries kernel '{kernel}' — "
                    "bitplane precisions run only the XNOR-popcount kernel"
                )
        elif kernel == "bitplane":
            errors.append(
                f"{path}: rows[{i}] affine engine '{engine}' claims the bitplane kernel"
            )
        elif kernel in ("panel", "rowmajor"):
            quant_kernels.setdefault(engine, set()).add(kernel)
    for engine, kernels in sorted(quant_kernels.items(), key=lambda kv: str(kv[0])):
        missing = {"panel", "rowmajor"} - kernels
        if missing:
            errors.append(
                f"{path}: {engine} rows lack kernel variant(s) {sorted(missing)} — "
                "the before/after comparison is incomplete"
            )
    swept = doc.get("precisions")
    if isinstance(swept, list):
        for label in swept:
            if label not in seen_engines:
                errors.append(
                    f"{path}: sweep lists precision '{label}' but no rows were emitted"
                )
    return errors


NOISE_ROW_KEYS = [
    "actor_precision",
    "bits",
    "actors",
    "env_steps",
    "train_steps",
    "broadcasts",
    "steps_per_sec",
    "final_return",
    "eval_reward",
]


def check_noise_rows(path: str, doc: dict) -> list:
    """BENCH_noise.json row schema: one row per actor-precision rung of
    the QeRL convergence ladder. The fp32 baseline row must be present
    (the noise-helps/noise-hurts comparison is meaningless without it),
    rungs must be unique, step counts positive, and the fp32-relative
    reward — when the renderer could compute it — must be a number,
    with the fp32 row's own ratio equal to 1."""
    errors = []
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return [f"{path}: 'rows' is not a list"]
    rungs = []
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{path}: rows[{i}] is not an object")
            continue
        for k in NOISE_ROW_KEYS:
            if k not in row:
                errors.append(f"{path}: rows[{i}] missing key '{k}'")
        rung = row.get("actor_precision")
        if not isinstance(rung, str) or not rung:
            errors.append(f"{path}: rows[{i}] actor_precision '{rung}' is not a label")
        else:
            rungs.append(rung)
        for k in ("env_steps", "train_steps"):
            v = row.get(k)
            if not (isinstance(v, (int, float)) and v > 0):
                errors.append(f"{path}: rows[{i}] {k} '{v}' is not positive")
        ratio = row.get("reward_vs_fp32")
        if ratio is not None and not isinstance(ratio, (int, float)):
            errors.append(f"{path}: rows[{i}] reward_vs_fp32 '{ratio}' is not a number")
        if rung == "fp32" and isinstance(ratio, (int, float)) and abs(ratio - 1.0) > 1e-9:
            errors.append(
                f"{path}: rows[{i}] fp32 reward_vs_fp32 is {ratio}, expected 1.0 — "
                "the baseline is not normalized against itself"
            )
    if "fp32" not in rungs:
        errors.append(f"{path}: no fp32 baseline row — the ladder has no reference rung")
    dupes = sorted({r for r in rungs if rungs.count(r) > 1})
    if dupes:
        errors.append(f"{path}: duplicate ladder rung(s) {dupes}")
    return errors


SERVE_ROW_KEYS = [
    "engine",
    "bits",
    "clients",
    "queries",
    "rejected",
    "qps",
    "p50_us",
    "p99_us",
    "mean_batch",
    "max_batch_seen",
    "batch_hist",
]


def check_serve_rows(path: str, doc: dict) -> list:
    """BENCH_serve.json row schema: every (precision x clients) cell
    carries the served-latency percentiles (ordered: p50 <= p99), a
    batch-size histogram, and a positive integer client count — the
    fields the serving trajectory is tracked by across PRs."""
    errors = []
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return [f"{path}: 'rows' is not a list"]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{path}: rows[{i}] is not an object")
            continue
        for k in SERVE_ROW_KEYS:
            if k not in row:
                errors.append(f"{path}: rows[{i}] missing key '{k}'")
        clients = row.get("clients")
        if not (isinstance(clients, (int, float)) and clients >= 1 and clients == int(clients)):
            errors.append(f"{path}: rows[{i}] clients '{clients}' is not a positive integer")
        if not isinstance(row.get("batch_hist"), list):
            errors.append(f"{path}: rows[{i}] batch_hist is not a list")
        p50, p99, queries = row.get("p50_us"), row.get("p99_us"), row.get("queries")
        if isinstance(queries, (int, float)) and queries > 0:
            if not (isinstance(p50, (int, float)) and isinstance(p99, (int, float))):
                errors.append(f"{path}: rows[{i}] latency percentiles are not numbers")
            elif not (0 < p50 <= p99):
                errors.append(
                    f"{path}: rows[{i}] percentiles out of order (p50 {p50}, p99 {p99})"
                )
    return errors


SNAPSHOT_ROW_KEYS = [
    "engine",
    "bits",
    "publishes",
    "publish_ms_mean",
    "bytes_per_fetch",
    "fetch_ms_p50",
    "fetch_ms_p99",
    "staleness_mean",
    "staleness_max",
    "versions",
    "logit_mismatches",
    "final_version",
]


def check_snapshot_rows(path: str, doc: dict) -> list:
    """BENCH_snapshot.json row schema: every precision cell carries the
    wire-distribution trajectory — strictly increasing snapshot versions
    (one per publish), a positive artifact byte size, ordered fetch
    percentiles (0 < p50 <= p99), and zero logit mismatches between the
    hydrated and in-process engines (the bit-identical guarantee)."""
    errors = []
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return [f"{path}: 'rows' is not a list"]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{path}: rows[{i}] is not an object")
            continue
        for k in SNAPSHOT_ROW_KEYS:
            if k not in row:
                errors.append(f"{path}: rows[{i}] missing key '{k}'")
        versions = row.get("versions")
        if not isinstance(versions, list) or not versions:
            errors.append(f"{path}: rows[{i}] versions is not a non-empty list")
        elif any(not isinstance(v, (int, float)) for v in versions):
            errors.append(f"{path}: rows[{i}] versions contains non-numbers")
        elif any(b <= a for a, b in zip(versions, versions[1:])):
            errors.append(
                f"{path}: rows[{i}] versions not strictly increasing: {versions}"
            )
        bytes_per_fetch = row.get("bytes_per_fetch")
        if not (isinstance(bytes_per_fetch, (int, float)) and bytes_per_fetch > 0):
            errors.append(
                f"{path}: rows[{i}] bytes_per_fetch '{bytes_per_fetch}' is not positive"
            )
        p50, p99 = row.get("fetch_ms_p50"), row.get("fetch_ms_p99")
        if not (isinstance(p50, (int, float)) and isinstance(p99, (int, float))):
            errors.append(f"{path}: rows[{i}] fetch percentiles are not numbers")
        elif not (0 < p50 <= p99):
            errors.append(
                f"{path}: rows[{i}] fetch percentiles out of order (p50 {p50}, p99 {p99})"
            )
        if row.get("logit_mismatches") != 0:
            errors.append(
                f"{path}: rows[{i}] logit_mismatches "
                f"{row.get('logit_mismatches')!r} — hydrated engine diverged"
            )
    return errors


FAULTS_ROW_KEYS = [
    "engine",
    "bits",
    "env_steps",
    "train_steps",
    "broadcasts",
    "restarts",
    "recovery_ms",
    "kills",
    "publishes_dropped",
    "hub_publish_failures",
    "connect_failures",
    "client_retries",
    "steps_lost",
    "ckpt_trains",
    "resume_trains",
    "clean_trains",
    "logit_mismatches",
    "resume_mismatches",
    "learner_restarts",
    "learner_recovery_ms",
    "wd_mismatches",
    "partition_windows",
    "serve_queries",
    "serve_mismatches",
    "slow_batches",
    "drain_rejected",
    "final_version",
]


def check_faults_rows(path: str, doc: dict) -> list:
    """BENCH_faults.json row schema: every precision cell must have
    absorbed at least one actor kill (restarts >= 1, with a non-negative
    recovery latency), restarted the learner through the watchdog at
    least once (learner_restarts >= 1, positive recovery latency — the
    scripted hang must actually trip the heartbeat deadline), healed at
    least one hub partition window, retried at least as often as
    connects were scripted to fail, lost a non-negative number of steps,
    and recovered bit-exactly — zero mismatches vs the fault-free run
    for the faulted leg, the checkpoint-resume leg, the watchdog leg,
    and the served logits. The serve leg must also have flagged the
    scripted straggler batch (slow_batches >= 1) and bounced the
    deliberately-retained drain client (drain_rejected >= 1, with
    serve_queries > 0 so the bounce happened on a live server, not an
    idle one). A nonzero mismatch count means a fault leaked into the
    numerics, which is the one thing the crash-safety layer exists to
    prevent."""
    errors = []
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return [f"{path}: 'rows' is not a list"]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errors.append(f"{path}: rows[{i}] is not an object")
            continue
        for k in FAULTS_ROW_KEYS:
            if k not in row:
                errors.append(f"{path}: rows[{i}] missing key '{k}'")
        for k in (
            "logit_mismatches",
            "resume_mismatches",
            "wd_mismatches",
            "serve_mismatches",
        ):
            if row.get(k) != 0:
                errors.append(
                    f"{path}: rows[{i}] {k} {row.get(k)!r} — recovery was not bit-exact"
                )
        restarts = row.get("restarts")
        if not (isinstance(restarts, (int, float)) and restarts >= 1):
            errors.append(
                f"{path}: rows[{i}] restarts '{restarts}' — the scripted kill "
                "was not absorbed by a respawn"
            )
        lr = row.get("learner_restarts")
        if not (isinstance(lr, (int, float)) and lr >= 1):
            errors.append(
                f"{path}: rows[{i}] learner_restarts '{lr}' — the scripted hang "
                "never tripped the watchdog"
            )
        lrec = row.get("learner_recovery_ms")
        if not (isinstance(lrec, (int, float)) and lrec > 0):
            errors.append(
                f"{path}: rows[{i}] learner_recovery_ms '{lrec}' — a restarted "
                "learner must report a positive recovery latency"
            )
        pw = row.get("partition_windows")
        if not (isinstance(pw, (int, float)) and pw >= 1):
            errors.append(
                f"{path}: rows[{i}] partition_windows '{pw}' — the scripted hub "
                "partition never opened"
            )
        sb = row.get("slow_batches")
        if not (isinstance(sb, (int, float)) and sb >= 1):
            errors.append(
                f"{path}: rows[{i}] slow_batches '{sb}' — the scripted straggler "
                "batch was not detected"
            )
        dr, sq = row.get("drain_rejected"), row.get("serve_queries")
        if not (isinstance(dr, (int, float)) and dr >= 1):
            errors.append(
                f"{path}: rows[{i}] drain_rejected '{dr}' — the retained client "
                "was never bounced during drain"
            )
        elif not (isinstance(sq, (int, float)) and sq > 0):
            errors.append(
                f"{path}: rows[{i}] serve_queries '{sq}' — drain bounced queries "
                "but the server never served any (drain accounting inconsistent)"
            )
        for k in ("recovery_ms", "steps_lost"):
            v = row.get(k)
            if not (isinstance(v, (int, float)) and v >= 0):
                errors.append(f"{path}: rows[{i}] {k} '{v}' is not a non-negative number")
        retries, failed = row.get("client_retries"), row.get("connect_failures")
        if isinstance(retries, (int, float)) and isinstance(failed, (int, float)):
            if retries < failed:
                errors.append(
                    f"{path}: rows[{i}] client_retries {retries} < connect_failures "
                    f"{failed} — a scripted connect fault was never retried"
                )
        at, total = row.get("ckpt_trains"), row.get("clean_trains")
        if isinstance(at, (int, float)) and isinstance(total, (int, float)):
            if not 0 < at < total:
                errors.append(
                    f"{path}: rows[{i}] ckpt_trains {at} not inside (0, {total}) — "
                    "the crash leg never checkpointed mid-run"
                )
    return errors


def check(path: str) -> list:
    errors = []
    name = path.rsplit("/", 1)[-1].rsplit(".", 1)[0]
    expected = EXPECTATIONS.get(name)
    if expected is None:
        return [f"{path}: no expectations registered for '{name}'"]
    keys, rows_key = expected
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        return [f"{path}: missing"]
    except json.JSONDecodeError as e:
        return [f"{path}: invalid JSON ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level is {type(doc).__name__}, expected object"]
    for k in keys:
        if k not in doc:
            errors.append(f"{path}: missing top-level key '{k}'")
    if rows_key and isinstance(doc.get(rows_key), list) and not doc[rows_key]:
        errors.append(f"{path}: '{rows_key}' is empty")
    if name == "BENCH_engines" and not errors:
        errors.extend(check_engine_rows(path, doc))
    if name == "BENCH_noise" and not errors:
        errors.extend(check_noise_rows(path, doc))
    if name == "BENCH_serve" and not errors:
        errors.extend(check_serve_rows(path, doc))
    if name == "BENCH_snapshot" and not errors:
        errors.extend(check_snapshot_rows(path, doc))
    if name == "BENCH_faults" and not errors:
        errors.extend(check_faults_rows(path, doc))
    return errors


def self_test() -> int:
    """Exercise the row checkers against synthetic good/bad docs so CI
    catches a broken checker, not just broken reports."""
    import copy
    import os
    import tempfile

    good = {
        "bench": "snapshot",
        "mlp": "64x256x256x8",
        "rows": [
            {
                "engine": "int4",
                "bits": 4,
                "publishes": 3,
                "publish_ms_mean": 1.5,
                "bytes_per_fetch": 44000,
                "fetch_ms_p50": 0.4,
                "fetch_ms_p99": 0.9,
                "staleness_mean": 0.0,
                "staleness_max": 0,
                "versions": [1, 2, 3],
                "logit_mismatches": 0,
                "final_version": 3,
            }
        ],
    }
    breakages = [
        ("versions go backwards", lambda d: d["rows"][0].update(versions=[1, 3, 2])),
        ("versions repeat", lambda d: d["rows"][0].update(versions=[1, 2, 2])),
        ("zero fetch bytes", lambda d: d["rows"][0].update(bytes_per_fetch=0)),
        ("p50 above p99", lambda d: d["rows"][0].update(fetch_ms_p50=2.0)),
        ("nonzero mismatches", lambda d: d["rows"][0].update(logit_mismatches=1)),
        ("missing key", lambda d: d["rows"][0].pop("staleness_max")),
        ("empty rows", lambda d: d.update(rows=[])),
    ]
    good_faults = {
        "bench": "faults",
        "rows": [
            {
                "engine": "int8",
                "bits": 8,
                "env_steps": 300,
                "train_steps": 100,
                "broadcasts": 10,
                "restarts": 1,
                "recovery_ms": 4.2,
                "kills": 1,
                "publishes_dropped": 1,
                "hub_publish_failures": 1,
                "connect_failures": 2,
                "client_retries": 2,
                "steps_lost": 14,
                "ckpt_trains": 60,
                "resume_trains": 40,
                "clean_trains": 100,
                "logit_mismatches": 0,
                "resume_mismatches": 0,
                "learner_restarts": 1,
                "learner_recovery_ms": 12.5,
                "wd_mismatches": 0,
                "partition_windows": 1,
                "serve_queries": 80,
                "serve_mismatches": 0,
                "slow_batches": 1,
                "drain_rejected": 1,
                "final_version": 10,
            }
        ],
    }
    faults_breakages = [
        ("faulted run diverged", lambda d: d["rows"][0].update(logit_mismatches=1)),
        ("resumed run diverged", lambda d: d["rows"][0].update(resume_mismatches=2)),
        ("kill not absorbed", lambda d: d["rows"][0].update(restarts=0)),
        ("negative recovery", lambda d: d["rows"][0].update(recovery_ms=-1.0)),
        ("retries below connect faults", lambda d: d["rows"][0].update(client_retries=1)),
        ("checkpoint at run end", lambda d: d["rows"][0].update(ckpt_trains=100)),
        ("missing key", lambda d: d["rows"][0].pop("steps_lost")),
        ("hang never tripped the watchdog", lambda d: d["rows"][0].update(learner_restarts=0)),
        (
            "watchdog restart without recovery latency",
            lambda d: d["rows"][0].update(learner_recovery_ms=0),
        ),
        ("watchdog resume diverged", lambda d: d["rows"][0].update(wd_mismatches=1)),
        ("partition window never opened", lambda d: d["rows"][0].update(partition_windows=0)),
        ("served logits diverged", lambda d: d["rows"][0].update(serve_mismatches=3)),
        ("straggler batch undetected", lambda d: d["rows"][0].update(slow_batches=0)),
        ("drain bounced nobody", lambda d: d["rows"][0].update(drain_rejected=0)),
        (
            "drain bounce on a server that served nothing",
            lambda d: d["rows"][0].update(serve_queries=0),
        ),
        ("missing drain column", lambda d: d["rows"][0].pop("drain_rejected")),
        ("empty rows", lambda d: d.update(rows=[])),
    ]
    def engine_row(engine, bits, kernel):
        return {
            "engine": engine,
            "bits": bits,
            "kernel": kernel,
            "threads": 1,
            "width": 512,
            "batch": 64,
            "rows_per_sec_scalar": 1e6,
            "rows_per_sec_batched": 4e6,
            "speedup": 4.0,
        }

    good_engines = {
        "bench": "engines",
        "mlp": "128xWxWx25",
        "bits": [32, 8, 1, 2],
        "precisions": ["fp32", "int8", "int1", "ternary"],
        "threads": 1,
        "headline_int8_b64_w512_speedup": 2.5,
        "int4_panel_vs_rowmajor_b64_w512": None,
        "int8_threads2_vs_1_b64": 1.3,
        "int1_vs_int8_b64_w512": 3.0,
        "rows": [
            engine_row("fp32", 32, "base"),
            engine_row("int8", 8, "panel"),
            engine_row("int8", 8, "rowmajor"),
            engine_row("int1", 1, "bitplane"),
            engine_row("ternary", 2, "bitplane"),
        ],
    }
    engines_breakages = [
        ("missing int1 headline key", lambda d: d.pop("int1_vs_int8_b64_w512")),
        ("missing precisions key", lambda d: d.pop("precisions")),
        (
            "int1 rows fell out of the sweep",
            lambda d: d.update(rows=[r for r in d["rows"] if r["engine"] != "int1"]),
        ),
        (
            "int1 row mistagged as panel",
            lambda d: d["rows"][3].update(kernel="panel"),
        ),
        (
            "affine row claims the bitplane kernel",
            lambda d: d["rows"][1].update(kernel="bitplane"),
        ),
        (
            "int8 rowmajor reference dropped",
            lambda d: d.update(rows=[r for r in d["rows"] if r["kernel"] != "rowmajor"]),
        ),
        ("unknown kernel tag", lambda d: d["rows"][0].update(kernel="simd")),
        ("missing row key", lambda d: d["rows"][4].pop("rows_per_sec_batched")),
    ]
    def noise_row(rung, bits, reward, ratio):
        row = {
            "actor_precision": rung,
            "bits": bits,
            "actors": 4,
            "env_steps": 3000,
            "train_steps": 1000,
            "broadcasts": 20,
            "steps_per_sec": 500.0,
            "final_return": reward,
            "eval_reward": reward,
        }
        if ratio is not None:
            row["reward_vs_fp32"] = ratio
        return row

    good_noise = {
        "bench": "noise",
        "env": "cartpole",
        "rows": [
            noise_row("fp32", 32, 180.0, 1.0),
            noise_row("int8", 8, 178.0, 178.0 / 180.0),
            noise_row("ternary", 2, 171.0, 171.0 / 180.0),
            noise_row("int1", 1, 150.0, 150.0 / 180.0),
        ],
    }
    noise_breakages = [
        (
            "fp32 baseline rung missing",
            lambda d: d.update(rows=[r for r in d["rows"] if r["actor_precision"] != "fp32"]),
        ),
        (
            "duplicate ladder rung",
            lambda d: d["rows"].append(copy.deepcopy(d["rows"][3])),
        ),
        ("zero env steps", lambda d: d["rows"][2].update(env_steps=0)),
        ("non-numeric ratio", lambda d: d["rows"][1].update(reward_vs_fp32="0.98")),
        ("fp32 not self-normalized", lambda d: d["rows"][0].update(reward_vs_fp32=0.5)),
        ("missing row key", lambda d: d["rows"][1].pop("eval_reward")),
        ("empty rows", lambda d: d.update(rows=[])),
    ]
    failures = []
    with tempfile.TemporaryDirectory() as tmp:

        def write_and_check(name, doc):
            path = os.path.join(tmp, name)
            with open(path, "w", encoding="utf-8") as f:
                json.dump(doc, f)
            return check(path)

        for name, pristine, planted in [
            ("BENCH_snapshot.json", good, breakages),
            ("BENCH_faults.json", good_faults, faults_breakages),
            ("BENCH_engines.json", good_engines, engines_breakages),
            ("BENCH_noise.json", good_noise, noise_breakages),
        ]:
            errs = write_and_check(name, pristine)
            if errs:
                failures.append(f"pristine {name} rejected: {errs}")
            for label, mutate in planted:
                doc = copy.deepcopy(pristine)
                mutate(doc)
                if not write_and_check(name, doc):
                    failures.append(f"breakage not caught in {name}: {label}")
    for f in failures:
        print(f"self-test failure: {f}", file=sys.stderr)
    if not failures:
        n = (
            len(breakages)
            + len(faults_breakages)
            + len(engines_breakages)
            + len(noise_breakages)
        )
        print(f"ok: self-test ({n} breakages caught)")
    return 1 if failures else 0


def main(argv: list) -> int:
    if argv == ["--self-test"]:
        return self_test()
    if not argv:
        print(
            "usage: check_bench_reports.py BENCH_*.json... | --self-test",
            file=sys.stderr,
        )
        return 1
    all_errors = []
    for path in argv:
        errs = check(path)
        if errs:
            all_errors.extend(errs)
        else:
            print(f"ok: {path}")
    for e in all_errors:
        print(f"error: {e}", file=sys.stderr)
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Regenerate the golden vectors pinned in rust/tests/quant_golden.rs.

Run `python -m tests.gen_golden` from python/ and paste the output into
the Rust test if the quantizer specification ever changes (it shouldn't:
the spec is paper §3.1).
"""

import numpy as np
import jax.numpy as jnp

from compile.kernels import ref


def main() -> None:
    rng = np.random.default_rng(42)
    x = rng.normal(0.5, 1.7, 16).astype(np.float32)
    print("pub const GOLDEN_X: [f32; 16] =", [float(v) for v in x], ";")
    for bits in [2, 4, 8]:
        y = np.asarray(ref.fake_quant_dynamic_ref(jnp.asarray(x), float(bits)))
        print(f"pub const GOLDEN_INT{bits}: [f32; 16] =", [float(v) for v in y], ";")
    y16 = np.asarray(ref.fp16_quant_ref(jnp.asarray(x)))
    print("pub const GOLDEN_FP16: [f32; 16] =", [float(v) for v in y16], ";")


if __name__ == "__main__":
    main()

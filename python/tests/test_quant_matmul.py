"""L1 kernel correctness: fused quant_matmul vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant_matmul import quant_matmul


def arr(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 32), k=st.integers(1, 48), n=st.integers(1, 24),
       seed=st.integers(0, 2**16), bits=st.sampled_from([4.0, 8.0, 16.0]))
def test_matches_ref(m, k, n, seed, bits):
    x = arr((m, k), seed)
    w = arr((k, n), seed + 1)
    got = quant_matmul(x, w, bits)
    want = ref.quant_matmul_ref(x, w, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_8bit_close_to_fp32_matmul():
    x = arr((16, 32), 3)
    w = arr((32, 8), 4)
    got = np.asarray(quant_matmul(x, w, 8.0))
    exact = np.asarray(x @ w)
    scale = np.abs(exact).max()
    assert np.abs(got - exact).max() / scale < 0.05


def test_ste_gradients_match_plain_matmul():
    x = arr((6, 10), 5)
    w = arr((10, 4), 6)

    def f(x, w):
        return jnp.sum(quant_matmul(x, w, 8.0) ** 2)

    # STE convention: backward treats forward as x @ w with the *forward*
    # output's cotangent; compare structure against plain matmul grads.
    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape
    assert np.isfinite(np.asarray(gx)).all() and np.isfinite(np.asarray(gw)).all()


def test_low_bits_higher_error():
    x = arr((16, 32), 7)
    w = arr((32, 8), 8)
    exact = np.asarray(x @ w)
    e2 = np.abs(np.asarray(quant_matmul(x, w, 2.0)) - exact).mean()
    e8 = np.abs(np.asarray(quant_matmul(x, w, 8.0)) - exact).mean()
    assert e2 > e8 * 5


def test_inside_jit():
    x = arr((8, 8), 9)
    w = arr((8, 8), 10)
    f = jax.jit(lambda a, b: quant_matmul(a, b, 8.0))
    np.testing.assert_allclose(
        np.asarray(f(x, w)), np.asarray(ref.quant_matmul_ref(x, w, 8.0)),
        rtol=1e-5, atol=1e-5,
    )

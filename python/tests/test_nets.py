"""L2 network tower: shapes, QAT insertion, layer norm, bf16 compute."""

import jax.numpy as jnp
import numpy as np

from compile.nets import mlp_apply, mlp_param_shapes, n_quant_tensors
from compile.quantization import QuantCtl, init_qstate


def make_params(dims, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(0, 0.2, s).astype(np.float32))
            for s in mlp_param_shapes(dims)]


def ctl_off():
    return QuantCtl(bits=jnp.float32(0.0), step=jnp.float32(0.0), delay=jnp.float32(0.0))


def ctl_on(bits):
    return QuantCtl(bits=jnp.float32(bits), step=jnp.float32(2.0), delay=jnp.float32(1.0))


def test_param_shapes():
    assert mlp_param_shapes([4, 8, 2]) == [(4, 8), (8,), (8, 2), (2,)]
    assert n_quant_tensors([4, 8, 2]) == 4


def test_forward_shapes_and_qstate_rows():
    dims = [6, 16, 16, 3]
    params = make_params(dims)
    x = jnp.zeros((5, 6))
    out, rows = mlp_apply(params, x, init_qstate(n_quant_tensors(dims)), 0, ctl_off())
    assert out.shape == (5, 3)
    assert len(rows) == n_quant_tensors(dims)


def test_quant_changes_output_but_not_catastrophically():
    dims = [4, 32, 2]
    params = make_params(dims, 3)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (8, 4)).astype(np.float32))
    qs = init_qstate(n_quant_tensors(dims))
    # monitoring pass to populate ranges
    _, rows = mlp_apply(params, x, qs, 0, ctl_off())
    qs = jnp.stack(rows)
    full, _ = mlp_apply(params, x, qs, 0, ctl_off())
    q8, _ = mlp_apply(params, x, qs, 0, ctl_on(8))
    q2, _ = mlp_apply(params, x, qs, 0, ctl_on(2))
    e8 = float(jnp.mean((full - q8) ** 2))
    e2 = float(jnp.mean((full - q2) ** 2))
    assert 0 < e8 < e2, (e8, e2)
    scale = float(jnp.mean(full**2)) + 1e-9
    assert e8 / scale < 0.05


def test_layer_norm_centers_hidden():
    # With layer_norm, scaling the input must barely change the output
    # (pre-activation normalization).
    dims = [4, 16, 2]
    params = make_params(dims, 5)
    x = jnp.ones((2, 4))
    qs = init_qstate(n_quant_tensors(dims))
    a, _ = mlp_apply(params, x, qs, 0, ctl_off(), layer_norm=True)
    b, _ = mlp_apply(params, x * 100.0, qs, 0, ctl_off(), layer_norm=True)
    # first-layer norm removes the scale; only bias pathways differ
    assert float(jnp.max(jnp.abs(a - b))) < 1.0


def test_bf16_compute_returns_f32_and_tracks_f32():
    dims = [4, 32, 2]
    params = make_params(dims, 7)
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (8, 4)).astype(np.float32))
    qs = init_qstate(n_quant_tensors(dims))
    full, _ = mlp_apply(params, x, qs, 0, ctl_off())
    half, _ = mlp_apply(params, x, qs, 0, ctl_off(), compute_dtype=jnp.bfloat16)
    assert half.dtype == jnp.float32
    rel = float(jnp.max(jnp.abs(full - half)) / (jnp.max(jnp.abs(full)) + 1e-9))
    assert rel < 0.1, rel


def test_final_tanh_bounds_output():
    dims = [3, 8, 2]
    params = [p * 10 for p in make_params(dims, 9)]
    x = jnp.ones((4, 3)) * 5
    qs = init_qstate(n_quant_tensors(dims))
    out, _ = mlp_apply(params, x, qs, 0, ctl_off(), final_activation="tanh")
    assert float(jnp.max(jnp.abs(out))) <= 1.0

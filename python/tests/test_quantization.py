"""L2 QAT plumbing: quant-delay semantics and range threading."""

import jax.numpy as jnp
import numpy as np

from compile.quantization import QuantCtl, init_qstate, qat_tensor


def ctl(bits, step, delay):
    return QuantCtl(
        bits=jnp.float32(bits), step=jnp.float32(step), delay=jnp.float32(delay)
    )


def test_monitoring_phase_passthrough_and_range_update():
    x = jnp.asarray(np.linspace(-2.0, 3.0, 12, dtype=np.float32))
    qs = init_qstate(1)
    out, row = qat_tensor(x, qs, 0, ctl(8, step=10, delay=100))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))  # untouched
    assert float(row[0]) == -2.0 and float(row[1]) == 3.0  # ranges absorbed


def test_ranges_accumulate_monotonically():
    qs = init_qstate(1).at[0].set(jnp.asarray([-1.0, 1.0]))
    x = jnp.asarray([0.5, -0.25], dtype=np.float32)
    _, row = qat_tensor(x, qs, 0, ctl(8, 0, 100))
    # narrower observation must not shrink the monitored range
    assert float(row[0]) == -1.0 and float(row[1]) == 1.0
    x2 = jnp.asarray([5.0, -3.0], dtype=np.float32)
    _, row2 = qat_tensor(x2, qs, 0, ctl(8, 0, 100))
    assert float(row2[0]) == -3.0 and float(row2[1]) == 5.0


def test_quantized_phase_freezes_ranges_and_quantizes():
    qs = init_qstate(1).at[0].set(jnp.asarray([-1.0, 1.0]))
    x = jnp.asarray(np.linspace(-1.0, 1.0, 9, dtype=np.float32))
    out, row = qat_tensor(x, qs, 0, ctl(2, step=200, delay=100))
    # ranges frozen
    np.testing.assert_array_equal(np.asarray(row), [-1.0, 1.0])
    # 2 bits over [-1, 1]: at most 4 distinct output values
    assert len(np.unique(np.asarray(out))) <= 4
    assert not np.allclose(np.asarray(out), np.asarray(x))


def test_bits_zero_disables_quantization_forever():
    qs = init_qstate(1).at[0].set(jnp.asarray([-1.0, 1.0]))
    x = jnp.asarray([0.123456, -0.654321], dtype=np.float32)
    out, _ = qat_tensor(x, qs, 0, ctl(0, step=10**9, delay=0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_exact_delay_boundary():
    qs = init_qstate(1).at[0].set(jnp.asarray([-1.0, 1.0]))
    x = jnp.asarray([0.37], dtype=np.float32)
    before, _ = qat_tensor(x, qs, 0, ctl(4, step=99, delay=100))
    at, _ = qat_tensor(x, qs, 0, ctl(4, step=100, delay=100))
    np.testing.assert_array_equal(np.asarray(before), np.asarray(x))
    assert float(at[0]) != float(x[0])  # quantized from the delay step on

"""Algorithm train-step sanity: every exported program must (a) match its
declared signature and (b) make optimization progress on a fixed batch."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.algos import a2c, ddpg, dqn, ppo
from compile.algos.common import ArchSpec


def arch(algo, obs=4, act=2, hidden=(16, 16), act_b=2, train_b=8):
    return ArchSpec(name=f"{algo}_t", obs_dim=obs, act_dim=act, hidden=hidden,
                    act_batch=act_b, train_batch=train_b)


def make_inputs(prog, seed=0):
    rng = np.random.default_rng(seed)
    arrs = []
    for name, shape in prog.inputs:
        if name == "hyper":
            arrs.append(None)  # filled by caller
        elif name in ("act", "actions"):
            arrs.append(jnp.zeros(shape, dtype=jnp.float32))
        elif name in ("done",):
            arrs.append(jnp.zeros(shape, dtype=jnp.float32))
        elif name == "isw":
            arrs.append(jnp.ones(shape, dtype=jnp.float32))
        elif name.startswith(("m.", "v.")) or name == "qstate":
            # optimizer state starts at zero (Adam's v must be >= 0);
            # range state starts empty
            arrs.append(jnp.zeros(shape, dtype=jnp.float32))
        else:
            arrs.append(jnp.asarray(rng.normal(0, 0.2, shape).astype(np.float32)))
    return arrs


def run_n(prog, arrs, hyper_fn, n_p_out, steps):
    """Iterate a train program feeding params back; return loss series."""
    losses = []
    names_in = [n for n, _ in prog.inputs]
    names_out = [n for n, _ in prog.outputs]
    for t in range(1, steps + 1):
        arrs[-1] = jnp.asarray(hyper_fn(t), dtype=jnp.float32)
        out = list(prog.fn(*arrs))
        assert len(out) == len(prog.outputs)
        # write back same-named outputs into same-named inputs
        for i_out, n_out in enumerate(names_out):
            if n_out in names_in and n_out not in ("loss",):
                arrs[names_in.index(n_out)] = out[i_out]
        li = names_out.index("loss") if "loss" in names_out else names_out.index("pg_loss")
        losses.append(float(out[li][0]))
    return losses


def test_dqn_reduces_loss_on_fixed_batch():
    prog = dqn.make_train(arch("dqn"))
    arrs = make_inputs(prog, 1)
    losses = run_n(prog, arrs, lambda t: [1e-3, 0.99, 0.0, t, 1e9, t], None, 40)
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def test_dqn_qat_still_learns():
    # Realistic QAT schedule: monitor ranges for 15 steps (quant delay),
    # then train with 8-bit fake quantization on the captured ranges.
    prog = dqn.make_train(arch("dqn"))
    arrs = make_inputs(prog, 2)
    losses = run_n(prog, arrs, lambda t: [1e-3, 0.99, 8.0, t, 15, t], None, 60)
    assert losses[-1] < losses[0] * 0.9, (losses[0], losses[-1])
    # loss keeps moving after quantization turns on (STE gradients flow)
    assert losses[-1] != losses[20]


def test_a2c_value_loss_decreases():
    prog = a2c.make_train(arch("a2c"))
    arrs = make_inputs(prog, 3)
    names_out = [n for n, _ in prog.outputs]
    vi = names_out.index("v_loss")
    names_in = [n for n, _ in prog.inputs]
    v_losses = []
    for t in range(1, 40):
        arrs[-1] = jnp.asarray([7e-3, 0.0, t, 1e9, t, 0.5, 0.0], dtype=jnp.float32)
        out = list(prog.fn(*arrs))
        for i_out, n_out in enumerate(names_out):
            if n_out in names_in:
                arrs[names_in.index(n_out)] = out[i_out]
        v_losses.append(float(out[vi][0]))
    assert v_losses[-1] < v_losses[0] * 0.5, (v_losses[0], v_losses[-1])


def test_ppo_clip_frac_sane_and_entropy_positive():
    prog = ppo.make_train(arch("ppo"))
    arrs = make_inputs(prog, 4)
    arrs[-1] = jnp.asarray([3e-4, 0.0, 1.0, 1e9, 1.0, 0.5, 0.01, 0.2], dtype=jnp.float32)
    out = list(prog.fn(*arrs))
    names_out = [n for n, _ in prog.outputs]
    clip_frac = float(out[names_out.index("clip_frac")][0])
    entropy = float(out[names_out.index("entropy")][0])
    assert 0.0 <= clip_frac <= 1.0
    assert entropy > 0.0


def test_ddpg_critic_loss_decreases():
    prog = ddpg.make_train(arch("ddpg", obs=3, act=1))
    arrs = make_inputs(prog, 5)
    names_out = [n for n, _ in prog.outputs]
    names_in = [n for n, _ in prog.inputs]
    ci = names_out.index("critic_loss")
    c_losses = []
    for t in range(1, 40):
        arrs[-1] = jnp.asarray([1e-4, 1e-3, 0.99, 0.0, t, 1e9, t], dtype=jnp.float32)
        out = list(prog.fn(*arrs))
        for i_out, n_out in enumerate(names_out):
            if n_out in names_in:
                arrs[names_in.index(n_out)] = out[i_out]
        c_losses.append(float(out[ci][0]))
    assert c_losses[-1] < c_losses[0] * 0.7, (c_losses[0], c_losses[-1])


def test_act_programs_shapes():
    for algo, mk, extra in [
        ("dqn", dqn.make_act, ("qvalues",)),
        ("a2c", a2c.make_act, ("logits", "value")),
        ("ppo", ppo.make_act, ("logits", "value")),
    ]:
        prog = mk(arch(algo))
        arrs = make_inputs(prog, 6)
        arrs[-1] = jnp.asarray([0.0, 0.0, 1.0], dtype=jnp.float32)
        out = prog.fn(*arrs)
        assert len(out) == len(prog.outputs)
        for o, (name, shape) in zip(out, prog.outputs):
            assert tuple(o.shape) == tuple(shape), (algo, name)


def test_ddpg_act_bounded():
    prog = ddpg.make_act(arch("ddpg", obs=3, act=2))
    arrs = make_inputs(prog, 7)
    arrs[-1] = jnp.asarray([0.0, 0.0, 1.0], dtype=jnp.float32)
    (action,) = prog.fn(*arrs)
    assert float(jnp.max(jnp.abs(action))) <= 1.0


def test_target_network_input_not_updated_by_train():
    # the DQN train program must not return new target params (the
    # coordinator owns the copy schedule)
    prog = dqn.make_train(arch("dqn"))
    out_names = [n for n, _ in prog.outputs]
    assert not any(n.startswith("target.") for n in out_names)

"""L1 kernel correctness: Pallas fake_quant vs the pure-jnp oracle.

Hypothesis sweeps shapes, value ranges, and bitwidths — the kernel must
match ref.py bit-for-bit (same float ops in the same order), and the
straight-through-estimator gradient must be exactly identity.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fake_quant import fake_quant, fake_quant_dynamic, fake_quant_per_axis

SHAPES = st.tuples(st.integers(1, 40), st.integers(1, 70))
BITS = st.sampled_from([1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0])


def arr(shape, seed, scale):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


@settings(max_examples=40, deadline=None)
@given(shape=SHAPES, seed=st.integers(0, 2**16), bits=BITS,
       scale=st.floats(1e-3, 1e3))
def test_dynamic_matches_ref(shape, seed, bits, scale):
    x = arr(shape, seed, scale)
    got = fake_quant_dynamic(x, bits)
    want = ref.fake_quant_dynamic_ref(x, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), bits=BITS,
       vmin=st.floats(-100.0, 0.0), vspan=st.floats(1e-3, 200.0))
def test_static_range_matches_ref(seed, bits, vmin, vspan):
    x = arr((17, 23), seed, max(abs(vmin), vspan))
    vmax = vmin + vspan
    got = fake_quant(x, jnp.float32(vmin), jnp.float32(vmax), bits)
    want = ref.fake_quant_ref(x, jnp.float32(vmin), jnp.float32(vmax), bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 24), cols=st.integers(1, 48),
       seed=st.integers(0, 2**16), bits=BITS)
def test_per_axis_matches_ref(rows, cols, seed, bits):
    w = arr((rows, cols), seed, 2.0)
    got = fake_quant_per_axis(w, bits)
    want = ref.fake_quant_per_axis_ref(w, bits, axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_grid_path_large_tensor():
    # > _BLOCK in both dims exercises the tiled pallas dispatch.
    x = arr((300, 520), 7, 1.0)
    got = fake_quant_dynamic(x, 8.0)
    want = ref.fake_quant_dynamic_ref(x, 8.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rank1_and_rank3_inputs():
    for shape in [(37,), (3, 5, 7)]:
        x = arr(shape, 3, 1.0)
        got = fake_quant_dynamic(x, 4.0)
        want = ref.fake_quant_dynamic_ref(x, 4.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_zero_always_representable():
    x = arr((8, 8), 1, 1.0)
    for bits in [2.0, 4.0, 8.0]:
        q = fake_quant(x.at[0, 0].set(0.0), jnp.min(x), jnp.max(x), bits)
        assert float(q[0, 0]) == 0.0


def test_ste_gradient_is_identity():
    x = arr((9, 11), 5, 1.0)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, jnp.min(x), jnp.max(x), 4.0) * 3.0))(x)
    np.testing.assert_array_equal(np.asarray(g), np.full_like(x, 3.0))


def test_ste_gradient_per_axis():
    w = arr((6, 10), 8, 1.0)
    g = jax.grad(lambda v: jnp.sum(fake_quant_per_axis(v, 4.0)))(w)
    np.testing.assert_array_equal(np.asarray(g), np.ones_like(w))


def test_quant_error_shrinks_with_bits():
    x = arr((64, 64), 11, 1.0)
    errs = []
    for bits in [2.0, 4.0, 8.0, 12.0]:
        q = fake_quant_dynamic(x, bits)
        errs.append(float(jnp.mean((q - x) ** 2)))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 1e-5


def test_all_zero_tensor_is_fixed_point():
    z = jnp.zeros((5, 5))
    q = fake_quant_dynamic(z, 8.0)
    np.testing.assert_array_equal(np.asarray(q), np.zeros((5, 5)))


def test_lowers_inside_jit():
    x = arr((16, 16), 2, 1.0)
    f = jax.jit(lambda v: fake_quant_dynamic(v, 8.0))
    np.testing.assert_array_equal(
        np.asarray(f(x)), np.asarray(ref.fake_quant_dynamic_ref(x, 8.0))
    )

"""AOT export path: registry consistency and HLO-text lowering."""

import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.algos import dqn
from compile.algos.common import ArchSpec
from compile.registry import build_matrix, CONTINUOUS_ENVS, DISCRETE_ENVS


def test_matrix_dedups_shared_signatures():
    programs, env_map = build_matrix()
    names = [spec.name for _, spec in programs]
    assert len(names) == len(set(names)), "arch names must be unique"
    # pong and breakout share (8, 3): one arch serves both
    assert env_map["a2c/pong_lite"] == env_map["a2c/breakout_lite"]
    # every mapped arch exists
    for arch in env_map.values():
        assert arch in names


def test_env_map_covers_paper_matrix():
    _, env_map = build_matrix()
    for env in ["breakout_lite", "pong_lite", "cartpole", "catcher",
                "invaders_lite", "grid_chase", "pyramid_hop", "diver_lite"]:
        for algo in ["dqn", "a2c", "ppo"]:
            assert f"{algo}/{env}" in env_map
    for env in ["walker_lite", "cheetah_lite", "biped_lite", "mc_continuous"]:
        assert f"ddpg/{env}" in env_map
    # case studies
    for p in ["mp_a", "mp_b", "mp_c"]:
        assert f"dqn/pong_lite/{p}" in env_map
        assert f"dqn/pong_lite/{p}_bf16" in env_map
    for p in ["nav_p1", "nav_p2", "nav_p3"]:
        assert f"dqn/nav_lite/{p}" in env_map
    assert "ppo/pong_lite/ln" in env_map


def test_registry_dims_positive():
    for env, (obs, act) in {**DISCRETE_ENVS, **CONTINUOUS_ENVS}.items():
        assert obs > 0 and act > 0, env


def test_lowering_produces_parseable_hlo_text():
    arch = ArchSpec(name="dqn_lower_t", obs_dim=3, act_dim=2, hidden=(8,),
                    act_batch=1, train_batch=4)
    prog = dqn.make_act(arch)
    text = aot.lower_program(prog)
    assert "ENTRY" in text and "f32" in text
    # return_tuple: root instruction is a tuple
    assert "tuple(" in text or "(f32[" in text


def test_lowered_fn_matches_eager():
    import jax
    arch = ArchSpec(name="dqn_lower_t2", obs_dim=3, act_dim=2, hidden=(8,),
                    act_batch=1, train_batch=4)
    prog = dqn.make_act(arch)
    rng = np.random.default_rng(0)
    arrs = [jnp.asarray(rng.normal(0, 0.2, s).astype(np.float32)) for _, s in prog.inputs]
    arrs[-1] = jnp.asarray([0.0, 0.0, 1.0], dtype=jnp.float32)
    eager = prog.fn(*arrs)
    jitted = jax.jit(prog.fn)(*arrs)
    np.testing.assert_allclose(np.asarray(eager[0]), np.asarray(jitted[0]), rtol=1e-6)


def test_program_entry_schema():
    arch = ArchSpec(name="dqn_lower_t3", obs_dim=3, act_dim=2, hidden=(8,),
                    act_batch=1, train_batch=4)
    prog = dqn.make_act(arch)
    entry = aot.program_entry(prog, "x.hlo.txt")
    assert entry["name"] == "dqn_lower_t3_act"
    assert entry["meta"]["algo"] == "dqn"
    assert all(set(t) == {"name", "shape"} for t in entry["inputs"])

"""Layer-2 policy/value networks.

QuaRL's Atari models are 3-conv + FC towers over pixel stacks; our
environment substrate (DESIGN.md §2) uses compact feature observations, so
the networks are multi-layer MLP towers of equivalent depth — preserving
the per-layer quantization-error composition the paper studies. All
networks are pure functions over a flat parameter list (order fixed,
recorded in the artifact manifest) so the Rust coordinator can thread
parameters through PJRT executions without any pytree machinery.

Parameter layout for an MLP with layer dims [d0, d1, ..., dL]:

    params = [W1 (d0,d1), b1 (d1,), W2 (d1,d2), b2 (d2,), ...]

QAT (see quantization.py) fake-quantizes every weight matrix and every
hidden activation; with ``layer_norm=True`` a parameter-free layer norm is
applied pre-activation (the Figure-1 regularization baseline).
"""

from typing import List, Sequence, Tuple

import jax.numpy as jnp

from .quantization import QuantCtl, qat_tensor


def mlp_param_shapes(dims: Sequence[int]) -> List[Tuple[int, ...]]:
    """Shapes of the flat parameter list for layer dims ``dims``."""
    shapes: List[Tuple[int, ...]] = []
    for i in range(len(dims) - 1):
        shapes.append((dims[i], dims[i + 1]))
        shapes.append((dims[i + 1],))
    return shapes


def n_quant_tensors(dims: Sequence[int]) -> int:
    """Quantized tensors for QAT state: one weight + one activation per layer.

    The final layer's output (logits / q-values / pre-tanh action) is also
    range-tracked, matching the paper's quantization of every activation.
    """
    n_layers = len(dims) - 1
    return 2 * n_layers


def _layer_norm(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5)


def mlp_apply(
    params: Sequence[jnp.ndarray],
    x: jnp.ndarray,
    qstate: jnp.ndarray,
    q_base: int,
    ctl: QuantCtl,
    *,
    activation: str = "relu",
    final_activation: str = "none",
    layer_norm: bool = False,
    compute_dtype=jnp.float32,
):
    """Forward pass with QAT fake-quant on weights and activations.

    Returns (output, new_qstate_rows). ``q_base`` indexes this tower's
    first row in the shared qstate array (multi-network algorithms like
    DDPG pack several towers into one state).

    ``compute_dtype=bfloat16`` gives the mixed-precision variant: params
    stay f32 (master copy), compute runs in bf16, output cast back — the
    scheme of Micikevicius et al. the paper's case study uses.
    """
    n_layers = len(params) // 2
    rows = []
    h = x.astype(compute_dtype)
    for i in range(n_layers):
        w = params[2 * i]
        b = params[2 * i + 1]
        w_eff, w_row = qat_tensor(w, qstate, q_base + 2 * i, ctl)
        rows.append(w_row)
        h = h @ w_eff.astype(compute_dtype) + b.astype(compute_dtype)
        last = i == n_layers - 1
        if not last:
            if layer_norm:
                h = _layer_norm(h)
            if activation == "relu":
                h = jnp.maximum(h, 0.0)
            elif activation == "tanh":
                h = jnp.tanh(h)
            else:
                raise ValueError(f"unknown activation {activation}")
        elif final_activation == "tanh":
            h = jnp.tanh(h)
        h32 = h.astype(jnp.float32)
        h_eff, a_row = qat_tensor(h32, qstate, q_base + 2 * i + 1, ctl)
        rows.append(a_row)
        h = h_eff.astype(compute_dtype)
    return h.astype(jnp.float32), rows

"""Optimizers for the AOT train steps.

Implemented over flat parameter lists (see nets.py) so the optimizer state
maps 1:1 onto the parameter tensors and the Rust coordinator can persist /
inspect it with the same machinery as the parameters themselves.
"""

from typing import List, Sequence, Tuple

import jax.numpy as jnp


def adam_update(
    params: Sequence[jnp.ndarray],
    grads: Sequence[jnp.ndarray],
    m: Sequence[jnp.ndarray],
    v: Sequence[jnp.ndarray],
    t: jnp.ndarray,
    lr: jnp.ndarray,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    max_grad_norm: float = 10.0,
) -> Tuple[List[jnp.ndarray], List[jnp.ndarray], List[jnp.ndarray]]:
    """One Adam step with global-norm gradient clipping.

    ``t`` is the 1-based step count (f32 scalar tensor, supplied by the
    coordinator) used for bias correction. Returns (params', m', v').
    """
    gsq = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads)
    gnorm = jnp.sqrt(gsq + 1e-12)
    scale = jnp.minimum(1.0, max_grad_norm / gnorm)

    b1t = jnp.power(beta1, t)
    b2t = jnp.power(beta2, t)
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        g = g.astype(jnp.float32) * scale
        mi2 = beta1 * mi + (1.0 - beta1) * g
        vi2 = beta2 * vi + (1.0 - beta2) * g * g
        m_hat = mi2 / (1.0 - b1t)
        v_hat = vi2 / (1.0 - b2t)
        new_p.append(p - lr * m_hat / (jnp.sqrt(v_hat) + eps))
        new_m.append(mi2)
        new_v.append(vi2)
    return new_p, new_m, new_v


def sgd_update(
    params: Sequence[jnp.ndarray],
    grads: Sequence[jnp.ndarray],
    lr: jnp.ndarray,
    max_grad_norm: float = 10.0,
) -> List[jnp.ndarray]:
    """Plain SGD with global-norm clipping (used by ablation benches)."""
    gsq = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads)
    gnorm = jnp.sqrt(gsq + 1e-12)
    scale = jnp.minimum(1.0, max_grad_norm / gnorm)
    return [p - lr * g.astype(jnp.float32) * scale for p, g in zip(params, grads)]

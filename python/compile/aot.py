"""AOT exporter: lower every (algorithm x architecture) program to HLO text.

This is the only place Python touches the pipeline; it runs once under
``make artifacts`` and writes

    artifacts/<program>.hlo.txt   one per act/train program
    artifacts/manifest.json       input/output specs + the env->arch map

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Incremental: a program is re-lowered only when missing or when --force is
given; the manifest is always rewritten (it is cheap and authoritative).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .algos import a2c, ddpg, dqn, ppo
from .registry import NAV_POLICIES, MP_POLICIES, build_matrix

FACTORIES = {
    "dqn": (dqn.make_act, dqn.make_train),
    "a2c": (a2c.make_act, a2c.make_train),
    "ppo": (ppo.make_act, ppo.make_train),
    "ddpg": (ddpg.make_act, ddpg.make_train),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_program(prog) -> str:
    specs = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in prog.inputs]
    lowered = jax.jit(prog.fn).lower(*specs)
    return to_hlo_text(lowered)


def program_entry(prog, filename: str) -> dict:
    return {
        "name": prog.name,
        "file": filename,
        "inputs": [{"name": n, "shape": list(s)} for n, s in prog.inputs],
        "outputs": [{"name": n, "shape": list(s)} for n, s in prog.outputs],
        "meta": prog.meta,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--force", action="store_true", help="re-lower everything")
    ap.add_argument("--only", default=None,
                    help="comma-separated arch-name substrings to export (debug)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    matrix, env_map = build_matrix()
    if args.only:
        keys = args.only.split(",")
        matrix = [(a, s) for a, s in matrix if any(k in s.name for k in keys)]

    entries = []
    t_total = time.time()
    for algo, spec in matrix:
        make_act, make_train = FACTORIES[algo]
        for prog in (make_act(spec), make_train(spec)):
            fname = f"{prog.name}.hlo.txt"
            path = os.path.join(args.out, fname)
            entries.append(program_entry(prog, fname))
            if os.path.exists(path) and not args.force:
                continue
            t0 = time.time()
            text = lower_program(prog)
            with open(path, "w") as f:
                f.write(text)
            print(f"  lowered {prog.name:48s} {len(text)//1024:6d} KiB "
                  f"{time.time()-t0:5.1f}s", file=sys.stderr)

    manifest = {
        "version": 1,
        "env_arch_map": env_map,
        "mp_policies": {k: list(v) for k, v in MP_POLICIES.items()},
        "nav_policies": {k: list(v) for k, v in NAV_POLICIES.items()},
        "programs": entries,
    }
    man_path = os.path.join(args.out, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    digest = hashlib.sha256(json.dumps(manifest, sort_keys=True).encode()).hexdigest()[:12]
    print(f"wrote {len(entries)} programs + manifest ({digest}) "
          f"in {time.time()-t_total:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()

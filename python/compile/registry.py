"""The QuaRL experiment matrix: architectures and the (algo, env) -> arch map.

This is the build-time mirror of paper Table 1. Architectures are deduped
by shape signature — two environments with the same (obs_dim, act_dim,
hidden) share one AOT program; the manifest's ``env_arch_map`` tells the
Rust coordinator which artifact serves which (algo, env) cell.

Environment shape signatures (must match rust/src/envs/):

    cartpole        obs 4   act 2    breakout_lite  obs 8   act 3
    pong_lite       obs 8   act 3    catcher        obs 6   act 3
    invaders_lite   obs 10  act 4    grid_chase     obs 12  act 5
    pyramid_hop     obs 9   act 4    diver_lite     obs 10  act 5
    acrobot         obs 6   act 3    mountain_car   obs 2   act 3
    mc_continuous   obs 2   act 1c   pendulum       obs 3   act 1c
    cheetah_lite    obs 12  act 4c   walker_lite    obs 12  act 4c
    biped_lite      obs 14  act 4c   nav_lite       obs 12  act 25
"""

from typing import Dict, List, Tuple

from .algos.common import ArchSpec

# (env id, obs_dim, act_dim) for each family.
DISCRETE_ENVS = {
    "cartpole": (4, 2),
    "pong_lite": (8, 3),
    "breakout_lite": (8, 3),
    "catcher": (6, 3),
    "invaders_lite": (10, 4),
    "grid_chase": (12, 5),
    "pyramid_hop": (9, 4),
    "diver_lite": (10, 5),
    "acrobot": (6, 3),
    "mountain_car": (2, 3),
}

CONTINUOUS_ENVS = {
    "mc_continuous": (2, 1),
    "pendulum": (3, 1),
    "cheetah_lite": (12, 4),
    "walker_lite": (12, 4),
    "biped_lite": (14, 4),
}

# Paper Table 1 evaluation cells (environment lists per algorithm).
ATARI8 = ["breakout_lite", "invaders_lite", "catcher", "grid_chase",
          "pyramid_hop", "diver_lite", "cartpole", "pong_lite"]
A2C_ENVS = ATARI8
PPO_ENVS = ATARI8
DQN_ENVS = ATARI8
DDPG_ENVS = ["walker_lite", "cheetah_lite", "biped_lite", "mc_continuous"]

# Extra canary/ablation cells beyond the paper matrix.
EXTRA = {
    "dqn": ["acrobot", "mountain_car"],
    "a2c": ["acrobot"],
    "ppo": ["acrobot"],
    "ddpg": ["pendulum"],
}

HIDDEN_SMALL = (64, 64)          # classic control
HIDDEN_ARCADE = (128, 128, 128)  # paper: 3-layer conv + FC tower analogue
HIDDEN_LOCO = (128, 128)         # continuous control

# Mixed-precision case study (paper Table 10): three DQN-Pong net sizes.
MP_POLICIES = {
    "mp_a": (128, 128, 128),
    "mp_b": (512, 512, 512),
    "mp_c": (1024, 1024, 2048),
}

# Deployment case study (paper Fig. 6): three NavLite DQN policies.
NAV_POLICIES = {
    "nav_p1": (64, 64, 64),
    "nav_p2": (256, 256, 256),
    "nav_p3": (4096, 512, 1024),
}
NAV_OBS, NAV_ACT = 12, 25


def _hidden_for(env: str) -> Tuple[int, ...]:
    if env in ("cartpole", "mountain_car", "acrobot", "mc_continuous", "pendulum"):
        return HIDDEN_SMALL
    if env in CONTINUOUS_ENVS:
        return HIDDEN_LOCO
    return HIDDEN_ARCADE


def _sig_name(algo: str, obs: int, act: int, hidden, ln: bool, compute: str) -> str:
    h = "x".join(str(x) for x in hidden)
    suffix = ("_ln" if ln else "") + ("_bf16" if compute == "bf16" else "")
    return f"{algo}_o{obs}a{act}h{h}{suffix}"


def build_matrix() -> Tuple[List[Tuple[str, ArchSpec]], Dict[str, str]]:
    """Returns (programs-to-export, env_arch_map).

    programs: [(algo, ArchSpec)] deduped by arch name.
    env_arch_map: "algo/env[/variant]" -> arch name.
    """
    batches = {
        "dqn": dict(act_batch=1, train_batch=64),
        "a2c": dict(act_batch=8, train_batch=128),
        "ppo": dict(act_batch=8, train_batch=128),
        "ddpg": dict(act_batch=1, train_batch=64),
    }
    archs: Dict[str, Tuple[str, ArchSpec]] = {}
    env_map: Dict[str, str] = {}

    def add(algo: str, env: str, obs: int, act: int, hidden, *, ln=False,
            compute="f32", key=None):
        name = _sig_name(algo, obs, act, hidden, ln, compute)
        if name not in archs:
            archs[name] = (algo, ArchSpec(
                name=name, obs_dim=obs, act_dim=act, hidden=tuple(hidden),
                layer_norm=ln, compute=compute, **batches[algo]))
        env_map[key or f"{algo}/{env}"] = name

    for algo, envs in (("dqn", DQN_ENVS), ("a2c", A2C_ENVS), ("ppo", PPO_ENVS)):
        for env in envs + EXTRA[algo]:
            obs, act = DISCRETE_ENVS[env]
            add(algo, env, obs, act, _hidden_for(env))
    for env in DDPG_ENVS + EXTRA["ddpg"]:
        obs, act = CONTINUOUS_ENVS[env]
        add("ddpg", env, obs, act, _hidden_for(env))

    # Figure 1: PPO with layer-norm regularization baseline (PongLite).
    obs, act = DISCRETE_ENVS["pong_lite"]
    add("ppo", "pong_lite", obs, act, HIDDEN_ARCADE, ln=True, key="ppo/pong_lite/ln")

    # Mixed precision (Table 4/10): DQN-Pong in three sizes, fp32 and bf16.
    obs, act = DISCRETE_ENVS["pong_lite"]
    for pol, hidden in MP_POLICIES.items():
        add("dqn", "pong_lite", obs, act, hidden, key=f"dqn/pong_lite/{pol}")
        add("dqn", "pong_lite", obs, act, hidden, compute="bf16",
            key=f"dqn/pong_lite/{pol}_bf16")

    # Deployment (Fig. 6): NavLite DQN policies I/II/III.
    for pol, hidden in NAV_POLICIES.items():
        add("dqn", "nav_lite", NAV_OBS, NAV_ACT, hidden, key=f"dqn/nav_lite/{pol}")

    programs = [(algo, spec) for (algo, spec) in archs.values()]
    return programs, env_map

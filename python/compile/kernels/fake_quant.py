"""Layer-1 Pallas kernels: uniform affine fake-quantization.

The fake-quant op is QuaRL's compute hot-spot: during quantization-aware
training it runs on every weight tensor and every activation tensor of
every forward pass. The kernels here implement the quantize-dequantize
(with the straight-through-estimator gradient of QuaRL §3.2) as Pallas
kernels so the HBM<->VMEM schedule is explicit.

TPU mapping (DESIGN.md §9): ``fake_quant`` is bandwidth-bound (2 HBM
touches per element); blocks of (256, 256) f32 keep a 256 KiB working set
in VMEM, leaving room for 4-deep double buffering. On this CPU image the
kernels run with ``interpret=True`` (the image's PJRT CPU plugin cannot
execute Mosaic custom-calls), so correctness — not wallclock — is what the
pytest suite validates; see ref.py for the oracle.

Straight-through estimator: the paper defines dQ/dW = I (full identity,
not range-clipped), so the custom VJP passes incoming cotangents through
unchanged for ``x`` and drops range/bit tangents.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block shape for tiled dispatch. 256x256 f32 = 256 KiB, sized for VMEM
# residency with double buffering on TPU; under interpret=True it only
# affects trace structure.
_BLOCK = 256


def _fake_quant_kernel(x_ref, ctl_ref, o_ref):
    """Elementwise quantize-dequantize of one block.

    ctl_ref holds (delta, z, levels) precomputed from the (global) range —
    the range reduction cannot live inside a blocked kernel without a
    cross-block pass, so the caller computes it (one cheap jnp reduction)
    and the kernel fuses the 5-op elementwise chain.
    """
    delta = ctl_ref[0]
    z = ctl_ref[1]
    levels = ctl_ref[2]
    x = x_ref[...]
    q = jnp.floor(x / delta) + z
    q = jnp.clip(q, 0.0, levels - 1.0)
    o_ref[...] = delta * (q - z)


def _fake_quant_2d(x2d, delta, z, levels):
    """Tiled pallas dispatch over a 2-D view of the tensor."""
    m, n = x2d.shape
    ctl = jnp.stack([delta, z, levels])
    if m <= _BLOCK and n <= _BLOCK:
        return pl.pallas_call(
            _fake_quant_kernel,
            out_shape=jax.ShapeDtypeStruct((m, n), x2d.dtype),
            interpret=True,
        )(x2d, ctl)
    grid = (pl.cdiv(m, _BLOCK), pl.cdiv(n, _BLOCK))
    return pl.pallas_call(
        _fake_quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_BLOCK, _BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK, _BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x2d.dtype),
        interpret=True,
    )(x2d, ctl)


def _as_2d(x):
    """View any-rank tensor as 2-D for the tiled kernel."""
    if x.ndim == 0:
        return x.reshape(1, 1), x.shape
    if x.ndim == 1:
        return x.reshape(1, -1), x.shape
    if x.ndim == 2:
        return x, x.shape
    return x.reshape(x.shape[0], -1), x.shape


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def fake_quant(x, vmin, vmax, n_bits):
    """Quantize-dequantize ``x`` to ``n_bits`` with static range [vmin, vmax].

    Matches ``ref.fake_quant_ref``. Gradient is the straight-through
    estimator (identity on ``x``; zero on range and bit inputs).
    """
    return _fake_quant_fwd(x, vmin, vmax, n_bits)[0]


def _fake_quant_fwd(x, vmin, vmax, n_bits):
    vmin = jnp.minimum(vmin, 0.0)
    vmax = jnp.maximum(vmax, 0.0)
    levels = jnp.exp2(jnp.asarray(n_bits, dtype=jnp.float32))
    delta = (jnp.abs(vmin) + jnp.abs(vmax)) / levels
    delta = jnp.where(delta <= 0.0, 1.0, delta)
    z = jnp.floor(-vmin / delta)
    x2d, orig_shape = _as_2d(x)
    out = _fake_quant_2d(x2d, delta, z, levels).reshape(orig_shape)
    return out, None


def _fake_quant_bwd(_res, g):
    # Straight-through estimator (QuaRL §3.2): dQ/dx = I.
    return g, jnp.zeros(()), jnp.zeros(()), jnp.zeros(())


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def fake_quant_dynamic(x, n_bits):
    """PTQ-style fake quant: range observed from ``x`` itself (still STE)."""
    vmin = jax.lax.stop_gradient(jnp.min(x))
    vmax = jax.lax.stop_gradient(jnp.max(x))
    return fake_quant(x, vmin, vmax, n_bits)


def _fake_quant_per_axis_kernel(w_ref, delta_ref, z_ref, lv_ref, o_ref):
    """Per-row (axis-0) affine quantize-dequantize of a 2-D weight block."""
    w = w_ref[...]
    delta = delta_ref[...].reshape(-1, 1)
    z = z_ref[...].reshape(-1, 1)
    levels = lv_ref[0]
    q = jnp.floor(w / delta) + z
    q = jnp.clip(q, 0.0, levels - 1.0)
    o_ref[...] = delta * (q - z)


@jax.custom_vjp
def fake_quant_per_axis(w, n_bits):
    """Per-axis (axis 0) fake quant for weight matrices, STE gradient.

    QuaRL applies per-axis quantization to conv channels; for our MLP
    towers axis 0 is the output-features axis, the analogous channel dim.
    """
    return _fq_pa_fwd(w, n_bits)[0]


def _fq_pa_fwd(w, n_bits):
    assert w.ndim == 2, "per-axis kernel expects rank-2 weights"
    vmin = jnp.minimum(jnp.min(w, axis=1), 0.0)
    vmax = jnp.maximum(jnp.max(w, axis=1), 0.0)
    levels = jnp.exp2(jnp.asarray(n_bits, dtype=jnp.float32))
    delta = (jnp.abs(vmin) + jnp.abs(vmax)) / levels
    delta = jnp.where(delta <= 0.0, 1.0, delta)
    z = jnp.floor(-vmin / delta)
    out = pl.pallas_call(
        _fake_quant_per_axis_kernel,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=True,
    )(w, delta, z, jnp.stack([levels]))
    return out, None


def _fq_pa_bwd(_res, g):
    return g, jnp.zeros(())


fake_quant_per_axis.defvjp(_fq_pa_fwd, _fq_pa_bwd)

"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the *specification*: the Pallas kernels in ``fake_quant.py`` and
``quant_matmul.py`` must match these bit-for-bit (they use the same float
ops in the same order), and the Rust quantizer in ``rust/src/quant/`` is
checked against golden vectors produced from these functions.

The math follows QuaRL §3.1/§3.2 (uniform affine quantization with zero
always representable, floor rounding, straight-through estimator):

    delta = (|min(W,0)| + |max(W,0)|) / 2^n
    z     = floor(-min(W,0) / delta)
    Q(W)  = clip(floor(W / delta) + z, 0, 2^n - 1)
    D(q)  = delta * (q - z)
"""

import jax.numpy as jnp


def qparams_from_range(vmin, vmax, n_bits):
    """delta (scale), z (zero point) and level count for the affine quantizer.

    ``vmin``/``vmax`` are expanded to include 0 per the paper (min(W,0),
    max(W,0)) so that 0 is always exactly representable. Degenerate
    all-zero ranges get delta=1 to avoid division by zero (then every
    value quantizes to z and dequantizes to exactly 0).
    """
    vmin = jnp.minimum(vmin, 0.0)
    vmax = jnp.maximum(vmax, 0.0)
    levels = jnp.exp2(jnp.asarray(n_bits, dtype=jnp.float32))
    delta = (jnp.abs(vmin) + jnp.abs(vmax)) / levels
    delta = jnp.where(delta <= 0.0, 1.0, delta)
    z = jnp.floor(-vmin / delta)
    return delta, z, levels


def fake_quant_ref(x, vmin, vmax, n_bits):
    """Quantize-dequantize ``x`` with static range [vmin, vmax].

    Returns values on the affine grid; out-of-range inputs are clamped to
    the representable span [D(0), D(2^n - 1)].
    """
    delta, z, levels = qparams_from_range(vmin, vmax, n_bits)
    q = jnp.floor(x / delta) + z
    q = jnp.clip(q, 0.0, levels - 1.0)
    return delta * (q - z)


def fake_quant_dynamic_ref(x, n_bits):
    """Post-training-quantization style: ranges taken from ``x`` itself."""
    return fake_quant_ref(x, jnp.min(x), jnp.max(x), n_bits)


def fake_quant_per_axis_ref(w, n_bits, axis=0):
    """Per-axis (channel) affine fake-quant, QuaRL's conv-weight scheme.

    Ranges are computed independently along ``axis`` (one scale/zero-point
    per slice), matching TFLite's per-channel quantization.
    """
    reduce_axes = tuple(i for i in range(w.ndim) if i != axis)
    vmin = jnp.min(w, axis=reduce_axes, keepdims=True)
    vmax = jnp.max(w, axis=reduce_axes, keepdims=True)
    return fake_quant_ref(w, vmin, vmax, n_bits)


def quant_matmul_ref(x, w, n_bits):
    """Simulated integer GEMM: dequantize(quantize(x) @ quantize(w)).

    Both operands are dynamically ranged (per-tensor). This is the oracle
    for the fused Pallas ``quant_matmul`` kernel and mirrors what an int8
    inference engine computes (up to f32 accumulation order).
    """
    dx, zx, lx = qparams_from_range(jnp.min(x), jnp.max(x), n_bits)
    dw, zw, lw = qparams_from_range(jnp.min(w), jnp.max(w), n_bits)
    qx = jnp.clip(jnp.floor(x / dx) + zx, 0.0, lx - 1.0) - zx
    qw = jnp.clip(jnp.floor(w / dw) + zw, 0.0, lw - 1.0) - zw
    return (dx * dw) * (qx @ qw)


def fp16_quant_ref(x):
    """fp16 post-training quantization: round-trip through IEEE half."""
    return x.astype(jnp.float16).astype(jnp.float32)

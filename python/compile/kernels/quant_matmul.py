"""Layer-1 Pallas kernel: fused quantized matmul.

This is the inference-path hot-spot the paper's deployment case study
exercises (TFLite int8 GEMM on the RasPi): quantize both operands to
``n_bits``, multiply on the integer grid, dequantize the accumulator.
Fusing all three stages into one kernel saves two full HBM round-trips
versus quantize -> write -> matmul -> write -> dequantize.

TPU mapping (DESIGN.md §9): (128, 128) operand tiles (64 KiB each) keep
x-tile, w-tile and the f32 accumulator resident in VMEM; the inner product
feeds the MXU while the quantize prologue / dequantize epilogue run on the
VPU. Under this image's CPU plugin we lower with ``interpret=True``
(numerics only; see ref.quant_matmul_ref for the oracle).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly tile edge. Interpret mode only inherits the trace structure.
_TILE = 128


def _qmm_kernel(x_ref, w_ref, ctl_ref, o_ref):
    """One (M,K)x(K,N) block: quantize -> integer-grid matmul -> dequantize.

    ctl = (dx, zx, dw, zw, levels): per-tensor scales/zero-points computed
    by the caller from global ranges (a blocked kernel cannot see the whole
    tensor for the range pass).
    """
    dx = ctl_ref[0]
    zx = ctl_ref[1]
    dw = ctl_ref[2]
    zw = ctl_ref[3]
    levels = ctl_ref[4]
    qx = jnp.clip(jnp.floor(x_ref[...] / dx) + zx, 0.0, levels - 1.0) - zx
    qw = jnp.clip(jnp.floor(w_ref[...] / dw) + zw, 0.0, levels - 1.0) - zw
    o_ref[...] = (dx * dw) * jnp.dot(qx, qw, preferred_element_type=jnp.float32)


def _qparams(v, levels):
    vmin = jnp.minimum(jnp.min(v), 0.0)
    vmax = jnp.maximum(jnp.max(v), 0.0)
    delta = (jnp.abs(vmin) + jnp.abs(vmax)) / levels
    delta = jnp.where(delta <= 0.0, 1.0, delta)
    z = jnp.floor(-vmin / delta)
    return delta, z


@jax.custom_vjp
def quant_matmul(x, w, n_bits):
    """Fused simulated-integer GEMM with straight-through gradients.

    Forward matches ``ref.quant_matmul_ref``; backward treats the op as a
    plain matmul of the *quantized* operands' dequantized values — i.e. the
    STE convention the paper uses for QAT layers.
    """
    out, _ = _qmm_fwd(x, w, n_bits)
    return out


def _qmm_fwd(x, w, n_bits):
    assert x.ndim == 2 and w.ndim == 2, "quant_matmul expects rank-2 operands"
    levels = jnp.exp2(jnp.asarray(n_bits, dtype=jnp.float32))
    dx, zx = _qparams(x, levels)
    dw, zw = _qparams(w, levels)
    ctl = jnp.stack([dx, zx, dw, zw, levels])
    out = pl.pallas_call(
        _qmm_kernel,
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w.shape[1]), jnp.float32),
        interpret=True,
    )(x, w, ctl)
    return out, (x, w)


def _qmm_bwd(res, g):
    x, w = res
    # STE: differentiate as if forward were x @ w.
    return g @ w.T, x.T @ g, jnp.zeros(())


quant_matmul.defvjp(_qmm_fwd, _qmm_bwd)

"""DQN act/train programs (Mnih et al. 2013) with QAT hooks.

Matches the paper's setup: a Q-network tower, target network, prioritized
replay importance weights, Huber TD loss, Adam. The target network is a
*separate parameter input* — the Rust coordinator owns the copy schedule
(`target_network_update_frequency` in the paper's Table 9) by duplicating
literals host-side, so no extra program is needed.

hyper layout (rank-1 f32):
    act:   [bits, step, delay]
    train: [lr, gamma, bits, step, delay, t_adam]
"""

from typing import List

import jax
import jax.numpy as jnp

from ..nets import mlp_apply
from ..optimizers import adam_update
from ..quantization import QuantCtl, assemble_qstate
from .common import ArchSpec, ProgramDef, huber, named_params, qstate_rows


def _unpack(arrs: List, counts: List[int]):
    """Split the flat positional arg list into algorithm groups."""
    out, i = [], 0
    for c in counts:
        out.append(list(arrs[i : i + c]))
        i += c
    assert i == len(arrs)
    return out


def make_act(arch: ArchSpec) -> ProgramDef:
    dims = arch.policy_dims()
    p_names = named_params("q", dims)
    n_p = len(p_names)
    n_q = qstate_rows(dims)
    B = arch.act_batch

    def fn(*arrs):
        (params,), rest = _unpack(arrs[:n_p], [n_p]), arrs[n_p:]
        qstate, obs, hyper = rest
        ctl = QuantCtl(bits=hyper[0], step=hyper[1], delay=hyper[2])
        qvals, _rows = mlp_apply(
            params, obs, qstate, 0, ctl,
            layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype,
        )
        return (qvals,)

    inputs = [*p_names, ("qstate", (n_q, 2)), ("obs", (B, arch.obs_dim)), ("hyper", (3,))]
    outputs = [("qvalues", (B, arch.act_dim))]
    return ProgramDef(
        name=f"{arch.name}_act", fn=fn, inputs=inputs, outputs=outputs,
        meta={"algo": "dqn", "kind": "act", "arch": arch._asdict(), "n_params": n_p,
              "n_qstate": n_q, "hyper": ["bits", "step", "delay"]},
    )


def make_train(arch: ArchSpec) -> ProgramDef:
    dims = arch.policy_dims()
    p_names = named_params("q", dims)
    n_p = len(p_names)
    n_q = qstate_rows(dims)
    B = arch.train_batch

    def fn(*arrs):
        params, target, m, v = _unpack(arrs[: 4 * n_p], [n_p, n_p, n_p, n_p])
        qstate, obs, act, rew, nobs, done, isw, hyper = arrs[4 * n_p :]
        lr, gamma, bits, step, delay, t_adam = (hyper[i] for i in range(6))
        ctl = QuantCtl(bits=bits, step=step, delay=delay)

        # Bellman target from the (frozen) target network — no QAT noise on
        # the target path; the paper quantizes the online net only.
        off = QuantCtl(bits=jnp.float32(0.0), step=step, delay=delay)
        q_next, _ = mlp_apply(target, nobs, qstate, 0, off,
                              layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype)
        y = rew + gamma * (1.0 - done) * jnp.max(q_next, axis=1)
        y = jax.lax.stop_gradient(y)

        def loss_fn(ps):
            q_all, rows = mlp_apply(ps, obs, qstate, 0, ctl,
                                    layer_norm=arch.layer_norm,
                                    compute_dtype=arch.compute_dtype)
            a = act.astype(jnp.int32)
            q_sa = jnp.take_along_axis(q_all, a[:, None], axis=1)[:, 0]
            td = q_sa - y
            loss = jnp.mean(isw * huber(td))
            return loss, (td, rows)

        (loss, (td, rows)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_p, new_m, new_v = adam_update(params, grads, m, v, t_adam, lr)
        new_qstate = assemble_qstate(rows)
        return (*new_p, *new_m, *new_v, new_qstate,
                loss.reshape(1), jnp.abs(td))

    inputs = [
        *p_names,
        *[(f"target.{n}", s) for n, s in p_names],
        *[(f"m.{n}", s) for n, s in p_names],
        *[(f"v.{n}", s) for n, s in p_names],
        ("qstate", (n_q, 2)),
        ("obs", (B, arch.obs_dim)),
        ("act", (B,)),
        ("rew", (B,)),
        ("nobs", (B, arch.obs_dim)),
        ("done", (B,)),
        ("isw", (B,)),
        ("hyper", (6,)),
    ]
    outputs = [
        *p_names,
        *[(f"m.{n}", s) for n, s in p_names],
        *[(f"v.{n}", s) for n, s in p_names],
        ("qstate", (n_q, 2)),
        ("loss", (1,)),
        ("td_abs", (B,)),
    ]
    return ProgramDef(
        name=f"{arch.name}_train", fn=fn, inputs=inputs, outputs=outputs,
        meta={"algo": "dqn", "kind": "train", "arch": arch._asdict(), "n_params": n_p,
              "n_qstate": n_q,
              "hyper": ["lr", "gamma", "bits", "step", "delay", "t_adam"]},
    )

"""PPO act/train programs (Schulman et al. 2017) with QAT hooks.

Clipped-surrogate objective over the same separate policy/value towers as
A2C (see a2c.py); the act program is identical in shape, so it reuses the
A2C factory with the algo tag swapped.

hyper layout (rank-1 f32):
    act:   [bits, step, delay]
    train: [lr, bits, step, delay, t_adam, vf_coef, ent_coef, clip]
"""

import jax
import jax.numpy as jnp

from ..nets import mlp_apply
from ..optimizers import adam_update
from ..quantization import QuantCtl, assemble_qstate
from . import a2c
from .common import ArchSpec, ProgramDef, categorical_logp_entropy, named_params, qstate_rows


def make_act(arch: ArchSpec) -> ProgramDef:
    prog = a2c.make_act(arch)
    prog.meta["algo"] = "ppo"
    return prog


def make_train(arch: ArchSpec) -> ProgramDef:
    pd, vd = arch.policy_dims(), arch.value_dims()
    pn, vn = named_params("pi", pd), named_params("vf", vd)
    n_all = len(pn) + len(vn)
    n_q = qstate_rows(pd)
    B = arch.train_batch

    def _split(arrs, counts):
        out, i = [], 0
        for c in counts:
            out.append(list(arrs[i : i + c]))
            i += c
        return out

    def fn(*arrs):
        params, m, v = _split(arrs[: 3 * n_all], [n_all, n_all, n_all])
        qstate, obs, actions, returns, adv, old_logp, hyper = arrs[3 * n_all :]
        lr, bits, step, delay, t_adam, vf_coef, ent_coef, clip = (hyper[i] for i in range(8))
        ctl = QuantCtl(bits=bits, step=step, delay=delay)
        off = QuantCtl(bits=jnp.float32(0.0), step=step, delay=delay)

        def loss_fn(ps):
            pp, vp = ps[: len(pn)], ps[len(pn) :]
            logits, rows = mlp_apply(pp, obs, qstate, 0, ctl,
                                     layer_norm=arch.layer_norm,
                                     compute_dtype=arch.compute_dtype)
            value, _ = mlp_apply(vp, obs, qstate, 0, off,
                                 layer_norm=arch.layer_norm,
                                 compute_dtype=arch.compute_dtype)
            logp, entropy = categorical_logp_entropy(logits, actions)
            ratio = jnp.exp(logp - old_logp)
            unclipped = ratio * adv
            clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
            pg_loss = -jnp.mean(jnp.minimum(unclipped, clipped))
            v_loss = jnp.mean((returns - value[:, 0]) ** 2)
            # Fraction of samples whose ratio was clipped — a standard PPO
            # health metric the coordinator logs.
            clip_frac = jnp.mean((jnp.abs(ratio - 1.0) > clip).astype(jnp.float32))
            loss = pg_loss + vf_coef * v_loss - ent_coef * entropy
            return loss, (pg_loss, v_loss, entropy, clip_frac, rows)

        (_, (pg_loss, v_loss, entropy, clip_frac, rows)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        new_p, new_m, new_v = adam_update(params, grads, m, v, t_adam, lr, max_grad_norm=0.5)
        return (*new_p, *new_m, *new_v, assemble_qstate(rows),
                pg_loss.reshape(1), v_loss.reshape(1), entropy.reshape(1),
                clip_frac.reshape(1))

    all_names = [*pn, *vn]
    inputs = [
        *all_names,
        *[(f"m.{n}", s) for n, s in all_names],
        *[(f"v.{n}", s) for n, s in all_names],
        ("qstate", (n_q, 2)),
        ("obs", (B, arch.obs_dim)),
        ("actions", (B,)),
        ("returns", (B,)),
        ("advantages", (B,)),
        ("old_logp", (B,)),
        ("hyper", (8,)),
    ]
    outputs = [
        *all_names,
        *[(f"m.{n}", s) for n, s in all_names],
        *[(f"v.{n}", s) for n, s in all_names],
        ("qstate", (n_q, 2)),
        ("pg_loss", (1,)),
        ("v_loss", (1,)),
        ("entropy", (1,)),
        ("clip_frac", (1,)),
    ]
    return ProgramDef(
        name=f"{arch.name}_train", fn=fn, inputs=inputs, outputs=outputs,
        meta={"algo": "ppo", "kind": "train", "arch": arch._asdict(),
              "n_policy_params": len(pn), "n_value_params": len(vn), "n_qstate": n_q,
              "hyper": ["lr", "bits", "step", "delay", "t_adam", "vf_coef", "ent_coef", "clip"]},
    )

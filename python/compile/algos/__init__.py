"""Pure-functional RL train/act steps, AOT-lowered per (algo, arch)."""

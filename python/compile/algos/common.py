"""Shared pieces for the algorithm train-step factories.

Every factory returns `ProgramDef`s: a pure function over a *flat* list of
f32 arrays plus the spec metadata the AOT exporter needs (input names /
shapes and output names). Flat positional tensors keep the Rust side free
of any pytree logic — the manifest is the single source of truth for
what each position means.
"""

from typing import Callable, List, NamedTuple, Sequence, Tuple

import jax.numpy as jnp

from ..nets import mlp_param_shapes, n_quant_tensors


class ArchSpec(NamedTuple):
    """One exported network architecture.

    name        - unique id, e.g. "dqn_pong_lite"
    obs_dim     - observation feature count
    act_dim     - discrete action count, or continuous action dims
    hidden      - hidden layer widths
    act_batch   - batch size of the act program (rollout width)
    train_batch - batch size of the train program
    layer_norm  - pre-activation layer norm (Fig-1 regularization baseline)
    compute    - "f32" or "bf16" (mixed-precision case study)
    """

    name: str
    obs_dim: int
    act_dim: int
    hidden: Tuple[int, ...]
    act_batch: int = 16
    train_batch: int = 64
    layer_norm: bool = False
    compute: str = "f32"

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.compute == "bf16" else jnp.float32

    def policy_dims(self) -> List[int]:
        return [self.obs_dim, *self.hidden, self.act_dim]

    def value_dims(self) -> List[int]:
        return [self.obs_dim, *self.hidden, 1]


class ProgramDef(NamedTuple):
    """A lowerable program: pure fn over flat f32 arrays.

    fn       - callable(*arrays) -> tuple(arrays)
    inputs   - [(name, shape)] in positional order
    outputs  - [(name, shape)]
    meta     - algorithm-specific metadata dict merged into the manifest
    """

    name: str
    fn: Callable
    inputs: List[Tuple[str, Tuple[int, ...]]]
    outputs: List[Tuple[str, Tuple[int, ...]]]
    meta: dict


def named_params(prefix: str, dims: Sequence[int]) -> List[Tuple[str, Tuple[int, ...]]]:
    """Manifest entries for one MLP's flat parameter list."""
    out = []
    for i, shape in enumerate(mlp_param_shapes(dims)):
        kind = "w" if len(shape) == 2 else "b"
        out.append((f"{prefix}.{kind}{i // 2}", shape))
    return out


def qstate_rows(dims: Sequence[int]) -> int:
    return n_quant_tensors(dims)


def categorical_logp_entropy(logits, actions):
    """Log-prob of taken actions and mean entropy for a batch of logits.

    ``actions`` arrives as f32 (the coordinator speaks a single dtype) and
    is cast to int for the gather.
    """
    logp_all = logits - jnp.log(jnp.sum(jnp.exp(logits - jnp.max(logits, axis=1, keepdims=True)), axis=1, keepdims=True)) - jnp.max(logits, axis=1, keepdims=True)
    a = actions.astype(jnp.int32)
    logp = jnp.take_along_axis(logp_all, a[:, None], axis=1)[:, 0]
    p = jnp.exp(logp_all)
    entropy = -jnp.sum(p * logp_all, axis=1).mean()
    return logp, entropy


def huber(x, delta: float = 1.0):
    absx = jnp.abs(x)
    quad = jnp.minimum(absx, delta)
    return 0.5 * quad * quad + delta * (absx - quad)

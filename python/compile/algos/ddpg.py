"""DDPG act/train programs (Lillicrap et al. 2015) with QAT hooks.

Actor tower (tanh-squashed, QAT-quantized — it is the deployed policy)
plus critic tower on [obs ++ action] (fp32). Target networks are separate
parameter inputs; the coordinator performs the polyak averaging host-side
(a cheap elementwise lerp) on its master copies.

hyper layout (rank-1 f32):
    act:   [bits, step, delay]
    train: [lr_actor, lr_critic, gamma, bits, step, delay, t_adam]
"""

import jax
import jax.numpy as jnp

from ..nets import mlp_apply
from ..optimizers import adam_update
from ..quantization import QuantCtl, assemble_qstate
from .common import ArchSpec, ProgramDef, named_params, qstate_rows


def _split(arrs, counts):
    out, i = [], 0
    for c in counts:
        out.append(list(arrs[i : i + c]))
        i += c
    return out


def _critic_dims(arch: ArchSpec):
    return [arch.obs_dim + arch.act_dim, *arch.hidden, 1]


def make_act(arch: ArchSpec) -> ProgramDef:
    ad = arch.policy_dims()
    an = named_params("actor", ad)
    n_q = qstate_rows(ad)
    B = arch.act_batch

    def fn(*arrs):
        actor = list(arrs[: len(an)])
        qstate, obs, hyper = arrs[len(an) :]
        ctl = QuantCtl(bits=hyper[0], step=hyper[1], delay=hyper[2])
        action, _ = mlp_apply(actor, obs, qstate, 0, ctl, final_activation="tanh",
                              layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype)
        return (action,)

    inputs = [*an, ("qstate", (n_q, 2)), ("obs", (B, arch.obs_dim)), ("hyper", (3,))]
    outputs = [("action", (B, arch.act_dim))]
    return ProgramDef(
        name=f"{arch.name}_act", fn=fn, inputs=inputs, outputs=outputs,
        meta={"algo": "ddpg", "kind": "act", "arch": arch._asdict(),
              "n_actor_params": len(an), "n_qstate": n_q,
              "hyper": ["bits", "step", "delay"]},
    )


def make_train(arch: ArchSpec) -> ProgramDef:
    ad, cd = arch.policy_dims(), _critic_dims(arch)
    an, cn = named_params("actor", ad), named_params("critic", cd)
    na, nc = len(an), len(cn)
    n_q = qstate_rows(ad)
    B = arch.train_batch

    def fn(*arrs):
        actor, critic, t_actor, t_critic, ma, va, mc, vc = _split(
            arrs[: 4 * na + 4 * nc], [na, nc, na, nc, na, na, nc, nc]
        )
        qstate, obs, act, rew, nobs, done, hyper = arrs[4 * na + 4 * nc :]
        lr_a, lr_c, gamma, bits, step, delay, t_adam = (hyper[i] for i in range(7))
        ctl = QuantCtl(bits=bits, step=step, delay=delay)
        off = QuantCtl(bits=jnp.float32(0.0), step=step, delay=delay)

        # --- critic update (targets from target nets, fp32 path) ---
        a_next, _ = mlp_apply(t_actor, nobs, qstate, 0, off, final_activation="tanh",
                              layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype)
        q_next, _ = mlp_apply(t_critic, jnp.concatenate([nobs, a_next], axis=1),
                              qstate, 0, off, layer_norm=arch.layer_norm,
                              compute_dtype=arch.compute_dtype)
        y = jax.lax.stop_gradient(rew + gamma * (1.0 - done) * q_next[:, 0])

        def critic_loss(cp):
            q, _ = mlp_apply(cp, jnp.concatenate([obs, act], axis=1), qstate, 0, off,
                             layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype)
            return jnp.mean((q[:, 0] - y) ** 2)

        c_loss, c_grads = jax.value_and_grad(critic_loss)(critic)
        new_c, new_mc, new_vc = adam_update(critic, c_grads, mc, vc, t_adam, lr_c)

        # --- actor update (through the pre-update critic, QAT on actor) ---
        def actor_loss(ap):
            a, rows = mlp_apply(ap, obs, qstate, 0, ctl, final_activation="tanh",
                                layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype)
            q, _ = mlp_apply(critic, jnp.concatenate([obs, a], axis=1), qstate, 0, off,
                             layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype)
            return -jnp.mean(q[:, 0]), rows

        (a_loss, rows), a_grads = jax.value_and_grad(actor_loss, has_aux=True)(actor)
        new_a, new_ma, new_va = adam_update(actor, a_grads, ma, va, t_adam, lr_a)

        return (*new_a, *new_c, *new_ma, *new_va, *new_mc, *new_vc,
                assemble_qstate(rows), c_loss.reshape(1), a_loss.reshape(1))

    inputs = [
        *an, *cn,
        *[(f"target.{n}", s) for n, s in an],
        *[(f"target.{n}", s) for n, s in cn],
        *[(f"m.{n}", s) for n, s in an],
        *[(f"v.{n}", s) for n, s in an],
        *[(f"m.{n}", s) for n, s in cn],
        *[(f"v.{n}", s) for n, s in cn],
        ("qstate", (n_q, 2)),
        ("obs", (B, arch.obs_dim)),
        ("act", (B, arch.act_dim)),
        ("rew", (B,)),
        ("nobs", (B, arch.obs_dim)),
        ("done", (B,)),
        ("hyper", (7,)),
    ]
    outputs = [
        *an, *cn,
        *[(f"m.{n}", s) for n, s in an],
        *[(f"v.{n}", s) for n, s in an],
        *[(f"m.{n}", s) for n, s in cn],
        *[(f"v.{n}", s) for n, s in cn],
        ("qstate", (n_q, 2)),
        ("critic_loss", (1,)),
        ("actor_loss", (1,)),
    ]
    return ProgramDef(
        name=f"{arch.name}_train", fn=fn, inputs=inputs, outputs=outputs,
        meta={"algo": "ddpg", "kind": "train", "arch": arch._asdict(),
              "n_actor_params": na, "n_critic_params": nc, "n_qstate": n_q,
              "hyper": ["lr_actor", "lr_critic", "gamma", "bits", "step", "delay", "t_adam"]},
    )

"""A2C act/train programs (Mnih et al. 2016) with QAT hooks.

Separate policy and value towers (stable-baselines' default MlpPolicy
layout the paper trains with). QAT applies to the *policy* network — the
deployed artifact — while the value tower stays fp32, mirroring the paper
quantizing the policy used for decisions.

hyper layout (rank-1 f32):
    act:   [bits, step, delay]
    train: [lr, bits, step, delay, t_adam, vf_coef, ent_coef]
"""

from typing import List

import jax
import jax.numpy as jnp

from ..nets import mlp_apply
from ..optimizers import adam_update
from ..quantization import QuantCtl, assemble_qstate
from .common import ArchSpec, ProgramDef, categorical_logp_entropy, named_params, qstate_rows


def _split(arrs, counts):
    out, i = [], 0
    for c in counts:
        out.append(list(arrs[i : i + c]))
        i += c
    assert i == len(arrs)
    return out


def make_act(arch: ArchSpec) -> ProgramDef:
    pd, vd = arch.policy_dims(), arch.value_dims()
    pn, vn = named_params("pi", pd), named_params("vf", vd)
    n_q = qstate_rows(pd)
    B = arch.act_batch

    def fn(*arrs):
        (pp, vp), rest = _split(arrs[: len(pn) + len(vn)], [len(pn), len(vn)]), arrs[len(pn) + len(vn) :]
        qstate, obs, hyper = rest
        ctl = QuantCtl(bits=hyper[0], step=hyper[1], delay=hyper[2])
        off = QuantCtl(bits=jnp.float32(0.0), step=hyper[1], delay=hyper[2])
        logits, _ = mlp_apply(pp, obs, qstate, 0, ctl,
                              layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype)
        value, _ = mlp_apply(vp, obs, qstate, 0, off,
                             layer_norm=arch.layer_norm, compute_dtype=arch.compute_dtype)
        return (logits, value[:, 0])

    inputs = [*pn, *vn, ("qstate", (n_q, 2)), ("obs", (B, arch.obs_dim)), ("hyper", (3,))]
    outputs = [("logits", (B, arch.act_dim)), ("value", (B,))]
    return ProgramDef(
        name=f"{arch.name}_act", fn=fn, inputs=inputs, outputs=outputs,
        meta={"algo": "a2c", "kind": "act", "arch": arch._asdict(),
              "n_policy_params": len(pn), "n_value_params": len(vn), "n_qstate": n_q,
              "hyper": ["bits", "step", "delay"]},
    )


def make_train(arch: ArchSpec) -> ProgramDef:
    pd, vd = arch.policy_dims(), arch.value_dims()
    pn, vn = named_params("pi", pd), named_params("vf", vd)
    n_all = len(pn) + len(vn)
    n_q = qstate_rows(pd)
    B = arch.train_batch

    def fn(*arrs):
        params, m, v = _split(arrs[: 3 * n_all], [n_all, n_all, n_all])
        qstate, obs, actions, returns, adv, hyper = arrs[3 * n_all :]
        lr, bits, step, delay, t_adam, vf_coef, ent_coef = (hyper[i] for i in range(7))
        ctl = QuantCtl(bits=bits, step=step, delay=delay)
        off = QuantCtl(bits=jnp.float32(0.0), step=step, delay=delay)

        def loss_fn(ps):
            pp, vp = ps[: len(pn)], ps[len(pn) :]
            logits, rows = mlp_apply(pp, obs, qstate, 0, ctl,
                                     layer_norm=arch.layer_norm,
                                     compute_dtype=arch.compute_dtype)
            value, _ = mlp_apply(vp, obs, qstate, 0, off,
                                 layer_norm=arch.layer_norm,
                                 compute_dtype=arch.compute_dtype)
            logp, entropy = categorical_logp_entropy(logits, actions)
            pg_loss = -jnp.mean(logp * adv)
            v_loss = jnp.mean((returns - value[:, 0]) ** 2)
            loss = pg_loss + vf_coef * v_loss - ent_coef * entropy
            return loss, (pg_loss, v_loss, entropy, rows)

        (_, (pg_loss, v_loss, entropy, rows)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        new_p, new_m, new_v = adam_update(params, grads, m, v, t_adam, lr, max_grad_norm=0.5)
        return (*new_p, *new_m, *new_v, assemble_qstate(rows),
                pg_loss.reshape(1), v_loss.reshape(1), entropy.reshape(1))

    all_names = [*pn, *vn]
    inputs = [
        *all_names,
        *[(f"m.{n}", s) for n, s in all_names],
        *[(f"v.{n}", s) for n, s in all_names],
        ("qstate", (n_q, 2)),
        ("obs", (B, arch.obs_dim)),
        ("actions", (B,)),
        ("returns", (B,)),
        ("advantages", (B,)),
        ("hyper", (7,)),
    ]
    outputs = [
        *all_names,
        *[(f"m.{n}", s) for n, s in all_names],
        *[(f"v.{n}", s) for n, s in all_names],
        ("qstate", (n_q, 2)),
        ("pg_loss", (1,)),
        ("v_loss", (1,)),
        ("entropy", (1,)),
    ]
    return ProgramDef(
        name=f"{arch.name}_train", fn=fn, inputs=inputs, outputs=outputs,
        meta={"algo": "a2c", "kind": "train", "arch": arch._asdict(),
              "n_policy_params": len(pn), "n_value_params": len(vn), "n_qstate": n_q,
              "hyper": ["lr", "bits", "step", "delay", "t_adam", "vf_coef", "ent_coef"]},
    )

"""Layer-2 public surface (re-exports).

``model.py`` is the conventional entry point named by the build layout;
the real definitions live in nets.py / quantization.py / algos/*. Import
from here in tests and notebooks.
"""

from .algos.common import ArchSpec, ProgramDef  # noqa: F401
from .nets import mlp_apply, mlp_param_shapes, n_quant_tensors  # noqa: F401
from .optimizers import adam_update, sgd_update  # noqa: F401
from .quantization import QuantCtl, init_qstate, qat_tensor  # noqa: F401
from .registry import build_matrix  # noqa: F401

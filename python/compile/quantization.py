"""QAT plumbing for Layer-2 networks (QuaRL §3.2).

Quantization-aware training threads a *range state* through every train
step: a ``(T, 2)`` f32 array holding the monitored (min, max) of each
quantized tensor (T = weights + activations, in network order). Before the
quantization-delay step the state keeps a running min/max and tensors pass
through unquantized; afterwards the captured ranges freeze and every
tensor is fake-quantized with them — exactly TensorFlow contrib.quantize's
``quant_delay`` semantics the paper uses.

All controls are *runtime tensor inputs* (bits, step, delay), so a single
AOT-lowered program serves the whole bitwidth sweep: bits = 0 disables
quantization entirely (the fp32 baseline uses the same artifact).
"""

from typing import NamedTuple

import jax.numpy as jnp

from .kernels.fake_quant import fake_quant


class QuantCtl(NamedTuple):
    """Scalar controls for QAT, unpacked from the ``hyper`` input vector.

    bits  - target bitwidth; 0 disables quantization (fp32 path).
    step  - current global training step.
    delay - quantization delay: steps of pure range monitoring.
    """

    bits: jnp.ndarray
    step: jnp.ndarray
    delay: jnp.ndarray

    @property
    def on(self):
        """Quantization active: bitwidth requested and past the delay."""
        return jnp.logical_and(self.bits >= 1.0, self.step >= self.delay)


def init_qstate(n_tensors: int) -> jnp.ndarray:
    """Fresh range state: all ranges empty (0, 0)."""
    return jnp.zeros((n_tensors, 2), dtype=jnp.float32)


def qat_tensor(x, qstate, idx, ctl: QuantCtl):
    """Apply QAT to one tensor; returns (maybe-quantized x, new (2,) range row).

    Monitoring phase (step < delay): ranges absorb the observed min/max and
    ``x`` passes through untouched. Quantized phase: ranges freeze, ``x``
    is fake-quantized against them with the straight-through estimator.
    """
    row = qstate[idx]
    obs_min = jnp.minimum(row[0], jnp.min(x))
    obs_max = jnp.maximum(row[1], jnp.max(x))
    new_row = jnp.where(ctl.on, row, jnp.stack([obs_min, obs_max]))
    xq = fake_quant(x, new_row[0], new_row[1], jnp.maximum(ctl.bits, 1.0))
    out = jnp.where(ctl.on, xq, x)
    return out, new_row


def assemble_qstate(rows):
    """Stack per-tensor range rows back into the (T, 2) state array."""
    return jnp.stack(rows, axis=0)
